//! The injector: executes a [`ChaosPlan`] against the op counter.
//!
//! [`crate::host::PimSystem`] consults an installed injector at every
//! injection boundary (launch, broadcast, push, scatter). Each
//! consultation advances the op counter by one, activates due events,
//! and returns what the boundary must do: fail with a typed error,
//! poison dead DPUs, and/or stretch modeled time. Everything is a pure
//! function of the plan and the op sequence — no wall clock, no
//! threads — so a failure run replays bit-identically from its seed.

use super::plan::{ChaosPlan, FaultEvent};
use crate::transfer::topology::{DpuId, SystemTopology};
use crate::util::error::{Error, FaultSite};
use std::collections::BTreeSet;

/// Deterministic counters describing what the injector actually did.
/// `PartialEq`/`Eq` so reproducibility tests compare whole runs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Total consultations (the op counter).
    pub ops: u64,
    /// Transient launch failures fired.
    pub launch_errors: u64,
    /// Transient transfer failures fired.
    pub transfer_errors: u64,
    /// DPUs marked dead (rank deaths expanded).
    pub dpu_deaths: u64,
    /// Consultations whose modeled time was straggler-stretched.
    pub straggled_ops: u64,
    /// Silent MRAM bit flips applied (launch boundaries).
    pub mram_flips: u64,
    /// Silent WRAM bit flips applied (launch boundaries).
    pub wram_flips: u64,
    /// In-flight transfer corruptions applied (transfer boundaries).
    pub transfer_corruptions: u64,
    /// Human-readable fire log, in op order.
    pub log: Vec<String>,
}

impl ChaosStats {
    /// Corruption events applied, all classes together — the integrity
    /// layer's `injected` count.
    pub fn corruptions_applied(&self) -> u64 {
        self.mram_flips + self.wram_flips + self.transfer_corruptions
    }
}

/// One bit flip the host must apply: XOR bit `bit` of the byte at
/// `addr` in the victim DPU's WRAM (`wram: true`) or MRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitFlip {
    pub dpu: DpuId,
    pub wram: bool,
    pub addr: u32,
    pub bit: u8,
}

/// What a launch boundary must do.
#[derive(Debug, Clone)]
pub struct LaunchOutcome {
    /// Fail the launch before any DPU executes (transient API failure).
    pub error: Option<Error>,
    /// Launched DPUs that are dead: poison each so its `launch_with`
    /// faults with `DeviceFailure` through the real fleet machinery.
    pub poison: Vec<DpuId>,
    /// Straggler multiplier for the launch's modeled compute seconds.
    pub factor: f64,
    /// Due silent bit flips (MRAM/WRAM): the host applies each to the
    /// victim DPU *before* the launch runs. Resident data rots between
    /// uses; the launch boundary is just the clock it rots on.
    pub flips: Vec<BitFlip>,
}

/// What a transfer boundary must do.
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    /// Fail the transfer before any byte moves.
    pub error: Option<Error>,
    /// Straggler multiplier for the transfer's modeled bus seconds.
    pub factor: f64,
    /// Due in-flight corruptions: the host applies each to the victim
    /// DPU's MRAM *after* the transfer's bytes land, so a
    /// verify-after-push readback of the same transfer observes them.
    pub flips: Vec<BitFlip>,
}

/// Plan executor, installed into a `PimSystem` via
/// [`crate::host::PimSystem::install_chaos`].
#[derive(Debug, Clone)]
pub struct ChaosInjector {
    plan: ChaosPlan,
    /// One flag per plan event: one-shot events fire exactly once.
    fired: Vec<bool>,
    op: u64,
    /// Permanently dead DPUs (poisoned on every launch that includes
    /// them, until quarantine removes them from the launched sets).
    dead: BTreeSet<DpuId>,
    stats: ChaosStats,
}

impl ChaosInjector {
    pub fn new(plan: ChaosPlan) -> ChaosInjector {
        let fired = vec![false; plan.events().len()];
        ChaosInjector { plan, fired, op: 0, dead: BTreeSet::new(), stats: ChaosStats::default() }
    }

    /// Consultations so far.
    pub fn op(&self) -> u64 {
        self.op
    }

    pub fn stats(&self) -> &ChaosStats {
        &self.stats
    }

    pub fn plan(&self) -> &ChaosPlan {
        &self.plan
    }

    /// DPUs currently dead under the plan.
    pub fn dead(&self) -> &BTreeSet<DpuId> {
        &self.dead
    }

    /// Advance the op counter and activate due permanent deaths.
    fn tick(&mut self, topo: &SystemTopology) {
        self.op += 1;
        self.stats.ops = self.op;
        for (i, ev) in self.plan.events().iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            match ev {
                FaultEvent::DpuDeath { at, dpu } if *at <= self.op => {
                    self.fired[i] = true;
                    if self.dead.insert(*dpu) {
                        self.stats.dpu_deaths += 1;
                    }
                    self.stats.log.push(format!("op {}: dpu {} died", self.op, dpu));
                }
                FaultEvent::RankDeath { at, rank } if *at <= self.op => {
                    self.fired[i] = true;
                    for d in topo.dpus_of_rank(*rank) {
                        if self.dead.insert(d) {
                            self.stats.dpu_deaths += 1;
                        }
                    }
                    self.stats.log.push(format!("op {}: rank {} died", self.op, rank));
                }
                _ => {}
            }
        }
    }

    /// Fire at most one due one-shot transient of the requested kind.
    fn fire_transient(&mut self, launch: bool) -> bool {
        for (i, ev) in self.plan.events().iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            let due = match ev {
                FaultEvent::TransientLaunch { at } if launch => *at <= self.op,
                FaultEvent::TransientTransfer { at } if !launch => *at <= self.op,
                _ => false,
            };
            if due {
                self.fired[i] = true;
                return true;
            }
        }
        false
    }

    /// Fire every due, un-fired corruption of the requested boundary
    /// kind (each one-shot), in plan order.
    fn fire_flips(&mut self, launch: bool) -> Vec<BitFlip> {
        let mut flips = Vec::new();
        for (i, ev) in self.plan.events().iter().enumerate() {
            if self.fired[i] {
                continue;
            }
            let hit = match ev {
                FaultEvent::MramBitFlip { at, dpu, addr, bit } if launch && *at <= self.op => {
                    Some((BitFlip { dpu: *dpu, wram: false, addr: *addr, bit: *bit }, "mram"))
                }
                FaultEvent::WramBitFlip { at, dpu, addr, bit } if launch && *at <= self.op => {
                    Some((BitFlip { dpu: *dpu, wram: true, addr: *addr, bit: *bit }, "wram"))
                }
                FaultEvent::TransferCorruption { at, dpu, addr, bit }
                    if !launch && *at <= self.op =>
                {
                    Some((BitFlip { dpu: *dpu, wram: false, addr: *addr, bit: *bit }, "transfer"))
                }
                _ => None,
            };
            if let Some((f, kind)) = hit {
                self.fired[i] = true;
                match kind {
                    "mram" => self.stats.mram_flips += 1,
                    "wram" => self.stats.wram_flips += 1,
                    _ => self.stats.transfer_corruptions += 1,
                }
                self.stats.log.push(format!(
                    "op {}: {} corruption (dpu {} addr {:#x} bit {})",
                    self.op, kind, f.dpu, f.addr, f.bit
                ));
                flips.push(f);
            }
        }
        flips
    }

    /// Plan events that have not fired yet, excluding stragglers and
    /// replica losses (the injector never marks those: stragglers are
    /// windows, replica losses belong to the serving harness). The
    /// accounting tests assert this drains empty — a planned event the
    /// run never applied is a test failure, not a silent no-op.
    pub fn unfired(&self) -> Vec<FaultEvent> {
        self.plan
            .events()
            .iter()
            .zip(&self.fired)
            .filter(|(e, &f)| {
                !f && !matches!(
                    e,
                    FaultEvent::Straggler { .. } | FaultEvent::ReplicaLoss { .. }
                )
            })
            .map(|(e, _)| e.clone())
            .collect()
    }

    fn straggle(&self, topo: &SystemTopology, ranks: &[usize]) -> f64 {
        let mut f = 1.0f64;
        for ev in self.plan.events() {
            if let FaultEvent::Straggler { from, to, socket, factor } = ev {
                if *from <= self.op
                    && self.op <= *to
                    && ranks.iter().any(|&r| topo.rank_loc(r).socket == *socket)
                {
                    f = f.max(*factor);
                }
            }
        }
        f
    }

    /// Non-incrementing straggler query for timing-only paths (bus
    /// reservations): evaluated at the *current* op.
    pub fn straggler_factor(&self, topo: &SystemTopology, ranks: &[usize]) -> f64 {
        self.straggle(topo, ranks)
    }

    /// Consult at a fleet-launch boundary (+1 op).
    pub fn on_launch(&mut self, topo: &SystemTopology, dpus: &[DpuId]) -> LaunchOutcome {
        self.tick(topo);
        let mut ranks: Vec<usize> = dpus.iter().map(|&d| topo.rank_of_dpu(d)).collect();
        ranks.sort_unstable();
        ranks.dedup();
        let factor = self.straggle(topo, &ranks);
        if factor > 1.0 {
            self.stats.straggled_ops += 1;
        }
        let poison: Vec<DpuId> =
            dpus.iter().copied().filter(|d| self.dead.contains(d)).collect();
        let error = if self.fire_transient(true) {
            self.stats.launch_errors += 1;
            let site = site_of(topo, dpus.first().copied());
            self.stats
                .log
                .push(format!("op {}: transient launch failure ({site})", self.op));
            Some(Error::LaunchFailed {
                site,
                transient: true,
                msg: format!("injected transient launch failure at op {}", self.op),
            })
        } else {
            None
        };
        let flips = self.fire_flips(true);
        LaunchOutcome { error, poison, factor, flips }
    }

    /// Consult at a transfer boundary (+1 op).
    pub fn on_transfer(&mut self, topo: &SystemTopology, ranks: &[usize]) -> TransferOutcome {
        self.tick(topo);
        let factor = self.straggle(topo, ranks);
        if factor > 1.0 {
            self.stats.straggled_ops += 1;
        }
        let error = if self.fire_transient(false) {
            self.stats.transfer_errors += 1;
            let rank = ranks.first().copied();
            let site = FaultSite {
                dpu: None,
                rank,
                socket: rank.map(|r| topo.rank_loc(r).socket),
            };
            self.stats
                .log
                .push(format!("op {}: transient transfer failure ({site})", self.op));
            Some(Error::TransferFailed {
                site,
                transient: true,
                msg: format!("injected transient transfer failure at op {}", self.op),
            })
        } else {
            None
        };
        // A transfer that failed moved no bytes — nothing to corrupt.
        // The flip stays pending and fires on the retry that lands.
        let flips = if error.is_none() { self.fire_flips(false) } else { Vec::new() };
        TransferOutcome { error, factor, flips }
    }
}

fn site_of(topo: &SystemTopology, dpu: Option<DpuId>) -> FaultSite {
    match dpu {
        Some(d) => {
            let r = topo.rank_of_dpu(d);
            FaultSite {
                dpu: Some(d),
                rank: Some(r),
                socket: Some(topo.rank_loc(r).socket),
            }
        }
        None => FaultSite::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::ErrorClass;

    fn topo() -> SystemTopology {
        SystemTopology::pristine()
    }

    #[test]
    fn dpu_death_activates_at_its_op_and_poisons_every_launch() {
        let plan = ChaosPlan::from_events(vec![FaultEvent::DpuDeath { at: 2, dpu: 5 }]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        let out = inj.on_launch(&t, &[4, 5, 6]);
        assert!(out.poison.is_empty(), "op 1 < at 2: nothing dead yet");
        assert!(out.error.is_none());
        let out = inj.on_launch(&t, &[4, 5, 6]);
        assert_eq!(out.poison, vec![5], "death active at op 2");
        // Permanent: still poisoned on later launches that include it.
        let out = inj.on_launch(&t, &[5]);
        assert_eq!(out.poison, vec![5]);
        // …but gone once quarantine removed it from the launched set.
        let out = inj.on_launch(&t, &[4, 6]);
        assert!(out.poison.is_empty());
        assert_eq!(inj.stats().dpu_deaths, 1);
    }

    #[test]
    fn rank_death_expands_to_all_64_dpus() {
        let plan = ChaosPlan::from_events(vec![FaultEvent::RankDeath { at: 1, rank: 2 }]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        let dpus: Vec<DpuId> = (2 * 64..3 * 64).collect();
        let out = inj.on_launch(&t, &dpus);
        assert_eq!(out.poison.len(), 64);
        assert_eq!(inj.stats().dpu_deaths, 64);
    }

    #[test]
    fn transients_fire_once_with_typed_context() {
        let plan = ChaosPlan::from_events(vec![
            FaultEvent::TransientLaunch { at: 1 },
            FaultEvent::TransientTransfer { at: 1 },
        ]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        let out = inj.on_launch(&t, &[130]); // rank 2, socket 0
        let e = out.error.expect("due transient fires");
        assert_eq!(e.class(), ErrorClass::Transient);
        assert_eq!(e.site().dpu, Some(130));
        assert_eq!(e.site().rank, Some(2));
        assert_eq!(e.site().socket, Some(0));
        // One-shot: the retry of the same launch succeeds.
        assert!(inj.on_launch(&t, &[130]).error.is_none());
        let out = inj.on_transfer(&t, &[3]);
        let e = out.error.expect("transfer transient fires");
        assert!(e.is_transient());
        assert_eq!(e.site().rank, Some(3));
        assert!(inj.on_transfer(&t, &[3]).error.is_none());
        assert_eq!(inj.stats().launch_errors, 1);
        assert_eq!(inj.stats().transfer_errors, 1);
        assert_eq!(inj.stats().ops, 4);
    }

    #[test]
    fn straggler_window_scales_matching_socket_only() {
        let plan = ChaosPlan::from_events(vec![FaultEvent::Straggler {
            from: 2,
            to: 3,
            socket: 1,
            factor: 3.0,
        }]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        // Socket-1 ranks start at TOTAL_RANKS/2 = 20.
        assert_eq!(inj.on_transfer(&t, &[20]).factor, 1.0, "op 1 before window");
        assert_eq!(inj.on_transfer(&t, &[20]).factor, 3.0, "op 2 in window");
        assert_eq!(inj.on_transfer(&t, &[1]).factor, 1.0, "socket 0 unaffected");
        assert_eq!(inj.on_transfer(&t, &[20]).factor, 1.0, "op 4 past window");
        assert_eq!(inj.stats().straggled_ops, 1);
    }

    #[test]
    fn bit_flips_fire_once_at_their_boundary_kind() {
        let plan = ChaosPlan::from_events(vec![
            FaultEvent::MramBitFlip { at: 1, dpu: 3, addr: 0x10_0040, bit: 5 },
            FaultEvent::WramBitFlip { at: 2, dpu: 4, addr: 0xE010, bit: 0 },
            FaultEvent::TransferCorruption { at: 1, dpu: 3, addr: 0x10_0008, bit: 7 },
        ]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        // Op 1 (launch): the MRAM flip is due; the WRAM flip is not;
        // the transfer corruption waits for a transfer boundary.
        let out = inj.on_launch(&t, &[3, 4]);
        assert_eq!(
            out.flips,
            vec![BitFlip { dpu: 3, wram: false, addr: 0x10_0040, bit: 5 }]
        );
        // Op 2 (transfer): corruption fires after the bytes land.
        let out = inj.on_transfer(&t, &[0]);
        assert_eq!(
            out.flips,
            vec![BitFlip { dpu: 3, wram: false, addr: 0x10_0008, bit: 7 }]
        );
        // Op 3 (launch): the WRAM flip is now due; nothing refires.
        let out = inj.on_launch(&t, &[3, 4]);
        assert_eq!(out.flips, vec![BitFlip { dpu: 4, wram: true, addr: 0xE010, bit: 0 }]);
        assert!(inj.on_launch(&t, &[3, 4]).flips.is_empty(), "one-shot");
        assert_eq!(inj.stats().mram_flips, 1);
        assert_eq!(inj.stats().wram_flips, 1);
        assert_eq!(inj.stats().transfer_corruptions, 1);
        assert_eq!(inj.stats().corruptions_applied(), 3);
        assert!(inj.unfired().is_empty(), "every planned event was applied");
    }

    #[test]
    fn transfer_corruption_defers_past_a_failed_transfer() {
        let plan = ChaosPlan::from_events(vec![
            FaultEvent::TransientTransfer { at: 1 },
            FaultEvent::TransferCorruption { at: 1, dpu: 0, addr: 0x10_0000, bit: 0 },
        ]);
        let mut inj = ChaosInjector::new(plan);
        let t = topo();
        let out = inj.on_transfer(&t, &[0]);
        assert!(out.error.is_some(), "transient fires first");
        assert!(out.flips.is_empty(), "no bytes moved, nothing corrupted");
        assert_eq!(inj.unfired().len(), 1, "corruption still pending");
        let out = inj.on_transfer(&t, &[0]);
        assert!(out.error.is_none());
        assert_eq!(out.flips.len(), 1, "fires on the retry that lands");
        assert!(inj.unfired().is_empty());
    }

    #[test]
    fn identical_consultation_sequences_yield_identical_stats() {
        let victims: Vec<DpuId> = (0..8).collect();
        let cfg = super::super::plan::ChaosConfig::default();
        let run = || {
            let plan = ChaosPlan::generate(42, &cfg, &victims);
            let mut inj = ChaosInjector::new(plan);
            let t = topo();
            for i in 0..40u64 {
                if i % 3 == 0 {
                    let _ = inj.on_transfer(&t, &[(i % 4) as usize]);
                } else {
                    let _ = inj.on_launch(&t, &[(i % 8) as usize, 8 + (i % 8) as usize]);
                }
            }
            inj.stats().clone()
        };
        assert_eq!(run(), run(), "same seed + same op sequence = same stats, exactly");
    }
}
