//! Fault plans: *what* goes wrong and exactly *when*, in injector ops.
//!
//! A [`ChaosPlan`] is either an explicit event list (tests pin op
//! arithmetic with these) or generated from a seed through the crate's
//! deterministic PRNG — the same seed always yields the same plan, and
//! the plan's op thresholds make the whole failure run reproducible.

use crate::transfer::topology::{DpuId, SOCKETS};
use crate::util::rng::Rng;

/// One scheduled failure. `at`/`from`/`to` are injector **op counts**
/// (see [`crate::chaos`] module docs), starting at 1 for the first
/// consulted operation.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Permanent death of one DPU: from op `at` on, every launch that
    /// includes it faults with `DeviceFailure` until it is quarantined.
    DpuDeath { at: u64, dpu: DpuId },
    /// Permanent death of a whole rank (all 64 of its DPUs).
    RankDeath { at: u64, rank: usize },
    /// One transient launch failure, fired at the first launch op
    /// `>= at` (one-shot: the identical retry succeeds).
    TransientLaunch { at: u64 },
    /// One transient transfer failure, fired at the first transfer op
    /// `>= at`.
    TransientTransfer { at: u64 },
    /// Modeled-latency multiplier on one socket over the op window
    /// `[from, to]` (results unchanged; only modeled seconds stretch).
    Straggler { from: u64, to: u64, socket: usize, factor: f64 },
    /// Loss of serving replica `replica` at op `at`. Consumed by the
    /// serving harness, not by `PimSystem` — replicas are a layer
    /// above the device plane.
    ReplicaLoss { at: u64, replica: usize },
}

impl FaultEvent {
    /// The op at which the event first takes effect (the sort key).
    pub fn at(&self) -> u64 {
        match self {
            FaultEvent::DpuDeath { at, .. }
            | FaultEvent::RankDeath { at, .. }
            | FaultEvent::TransientLaunch { at }
            | FaultEvent::TransientTransfer { at }
            | FaultEvent::ReplicaLoss { at, .. } => *at,
            FaultEvent::Straggler { from, .. } => *from,
        }
    }
}

/// Knobs for seeded plan generation ([`ChaosPlan::generate`]).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Horizon: every event lands at an op in `[1, ops]`.
    pub ops: u64,
    /// Permanent single-DPU deaths, drawn from the caller's victim list
    /// (the caller restricts victims so every shard keeps coverage).
    pub dpu_deaths: usize,
    /// One-shot transient launch failures.
    pub transient_launches: usize,
    /// One-shot transient transfer failures.
    pub transient_transfers: usize,
    /// Straggler windows (random socket, window within the horizon).
    pub stragglers: usize,
    /// Stragglers slow their socket by an integer factor in
    /// `[2, straggler_max_factor]`.
    pub straggler_max_factor: u64,
    /// Replica-loss events (0 disables).
    pub replica_losses: usize,
    /// Replica count the losses index into (0 disables).
    pub replicas: usize,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            ops: 64,
            dpu_deaths: 2,
            transient_launches: 2,
            transient_transfers: 1,
            stragglers: 1,
            straggler_max_factor: 4,
            replica_losses: 0,
            replicas: 0,
        }
    }
}

/// A schedule of [`FaultEvent`]s, sorted by activation op.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChaosPlan {
    events: Vec<FaultEvent>,
}

impl ChaosPlan {
    /// Build from an explicit event list (sorted by activation op;
    /// ties keep the given order).
    pub fn from_events(mut events: Vec<FaultEvent>) -> ChaosPlan {
        events.sort_by_key(|e| e.at());
        ChaosPlan { events }
    }

    /// Seeded generation: the same `(seed, cfg, victims)` triple always
    /// yields the same plan. Permanent deaths are drawn from `victims`
    /// only — pass the DPUs whose loss the topology can absorb (e.g.
    /// every shard's tail DPUs), so a generated plan always leaves ≥1
    /// usable DPU per shard and the keystone bit-exactness property
    /// holds.
    pub fn generate(seed: u64, cfg: &ChaosConfig, victims: &[DpuId]) -> ChaosPlan {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        let mut pool: Vec<DpuId> = victims.to_vec();
        rng.shuffle(&mut pool);
        for &dpu in pool.iter().take(cfg.dpu_deaths) {
            events.push(FaultEvent::DpuDeath { at: rng.range_u64(1, cfg.ops), dpu });
        }
        for _ in 0..cfg.transient_launches {
            events.push(FaultEvent::TransientLaunch { at: rng.range_u64(1, cfg.ops) });
        }
        for _ in 0..cfg.transient_transfers {
            events.push(FaultEvent::TransientTransfer { at: rng.range_u64(1, cfg.ops) });
        }
        for _ in 0..cfg.stragglers {
            let from = rng.range_u64(1, cfg.ops);
            events.push(FaultEvent::Straggler {
                from,
                to: from + rng.range_u64(1, cfg.ops),
                socket: rng.below(SOCKETS as u64) as usize,
                factor: rng.range_u64(2, cfg.straggler_max_factor.max(2)) as f64,
            });
        }
        if cfg.replicas > 0 {
            for _ in 0..cfg.replica_losses {
                events.push(FaultEvent::ReplicaLoss {
                    at: rng.range_u64(1, cfg.ops),
                    replica: rng.below(cfg.replicas as u64) as usize,
                });
            }
        }
        ChaosPlan::from_events(events)
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// `(at, replica)` pairs in activation order — the serving harness
    /// consumes these (the device-plane injector ignores them).
    pub fn replica_losses(&self) -> Vec<(u64, usize)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::ReplicaLoss { at, replica } => Some((*at, *replica)),
                _ => None,
            })
            .collect()
    }

    /// DPUs the plan kills outright via [`FaultEvent::DpuDeath`]
    /// (rank deaths are expanded against the topology at fire time).
    pub fn dead_dpus(&self) -> Vec<DpuId> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::DpuDeath { dpu, .. } => Some(*dpu),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_events_sorts_by_activation_op() {
        let plan = ChaosPlan::from_events(vec![
            FaultEvent::TransientLaunch { at: 9 },
            FaultEvent::Straggler { from: 2, to: 5, socket: 0, factor: 2.0 },
            FaultEvent::DpuDeath { at: 4, dpu: 7 },
        ]);
        let ats: Vec<u64> = plan.events().iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![2, 4, 9]);
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let victims: Vec<DpuId> = (0..32).collect();
        let cfg = ChaosConfig::default();
        let a = ChaosPlan::generate(11, &cfg, &victims);
        let b = ChaosPlan::generate(11, &cfg, &victims);
        assert_eq!(a, b, "same seed, same plan");
        let c = ChaosPlan::generate(12, &cfg, &victims);
        assert_ne!(a, c, "different seed, different plan");
    }

    #[test]
    fn generation_respects_config_counts_and_bounds() {
        let victims: Vec<DpuId> = (100..140).collect();
        let cfg = ChaosConfig {
            ops: 32,
            dpu_deaths: 3,
            transient_launches: 2,
            transient_transfers: 2,
            stragglers: 2,
            straggler_max_factor: 5,
            replica_losses: 2,
            replicas: 4,
        };
        let plan = ChaosPlan::generate(77, &cfg, &victims);
        assert_eq!(plan.len(), 3 + 2 + 2 + 2 + 2);
        assert_eq!(plan.dead_dpus().len(), 3);
        for d in plan.dead_dpus() {
            assert!(victims.contains(&d), "deaths drawn from the victim list only");
        }
        for e in plan.events() {
            assert!(e.at() >= 1 && e.at() <= 32, "activation in [1, ops]: {e:?}");
            match e {
                FaultEvent::Straggler { from, to, socket, factor } => {
                    assert!(to > from);
                    assert!(*socket < SOCKETS);
                    assert!(*factor >= 2.0 && *factor <= 5.0);
                }
                FaultEvent::ReplicaLoss { replica, .. } => assert!(*replica < 4),
                _ => {}
            }
        }
        assert_eq!(plan.replica_losses().len(), 2);
    }

    #[test]
    fn deaths_capped_by_victim_list() {
        let cfg = ChaosConfig { dpu_deaths: 10, ..ChaosConfig::default() };
        let plan = ChaosPlan::generate(5, &cfg, &[3, 4]);
        assert_eq!(plan.dead_dpus().len(), 2, "cannot kill more DPUs than offered");
    }
}
