//! Fault plans: *what* goes wrong and exactly *when*, in injector ops.
//!
//! A [`ChaosPlan`] is either an explicit event list (tests pin op
//! arithmetic with these) or generated from a seed through the crate's
//! deterministic PRNG — the same seed always yields the same plan, and
//! the plan's op thresholds make the whole failure run reproducible.

use crate::transfer::topology::{DpuId, SOCKETS};
use crate::util::rng::Rng;

/// Domain separator for the corruption subseed: the corruption draws
/// come from `Rng::new(seed ^ CORRUPTION_DOMAIN)`, never from the main
/// stream, so adding corruption knobs cannot perturb the plans existing
/// seeds generate (pinned by `corruption_free_plans_are_stable`).
const CORRUPTION_DOMAIN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One scheduled failure. `at`/`from`/`to` are injector **op counts**
/// (see [`crate::chaos`] module docs), starting at 1 for the first
/// consulted operation.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Permanent death of one DPU: from op `at` on, every launch that
    /// includes it faults with `DeviceFailure` until it is quarantined.
    DpuDeath { at: u64, dpu: DpuId },
    /// Permanent death of a whole rank (all 64 of its DPUs).
    RankDeath { at: u64, rank: usize },
    /// One transient launch failure, fired at the first launch op
    /// `>= at` (one-shot: the identical retry succeeds).
    TransientLaunch { at: u64 },
    /// One transient transfer failure, fired at the first transfer op
    /// `>= at`.
    TransientTransfer { at: u64 },
    /// Modeled-latency multiplier on one socket over the op window
    /// `[from, to]` (results unchanged; only modeled seconds stretch).
    Straggler { from: u64, to: u64, socket: usize, factor: f64 },
    /// Loss of serving replica `replica` at op `at`. Consumed by the
    /// serving harness, not by `PimSystem` — replicas are a layer
    /// above the device plane.
    ReplicaLoss { at: u64, replica: usize },
    /// One silent bit flip in the victim DPU's **MRAM**, applied at the
    /// first launch op `>= at`, *before* the launch runs (resident data
    /// rots between uses — the no-ECC DRAM-bank failure mode). The
    /// launch itself proceeds; detection is the scrub/readback layer's
    /// job.
    MramBitFlip { at: u64, dpu: DpuId, addr: u32, bit: u8 },
    /// One silent bit flip in the victim DPU's **WRAM**, applied at the
    /// first launch op `>= at`, before the launch runs.
    WramBitFlip { at: u64, dpu: DpuId, addr: u32, bit: u8 },
    /// One silent bit flip applied at the first transfer op `>= at`,
    /// *after* that transfer's bytes land (data corrupted in flight on
    /// the host↔PIM bus) — so a verify-after-push readback of the same
    /// transfer sees it.
    TransferCorruption { at: u64, dpu: DpuId, addr: u32, bit: u8 },
}

impl FaultEvent {
    /// The op at which the event first takes effect (the sort key).
    pub fn at(&self) -> u64 {
        match self {
            FaultEvent::DpuDeath { at, .. }
            | FaultEvent::RankDeath { at, .. }
            | FaultEvent::TransientLaunch { at }
            | FaultEvent::TransientTransfer { at }
            | FaultEvent::ReplicaLoss { at, .. }
            | FaultEvent::MramBitFlip { at, .. }
            | FaultEvent::WramBitFlip { at, .. }
            | FaultEvent::TransferCorruption { at, .. } => *at,
            FaultEvent::Straggler { from, .. } => *from,
        }
    }
}

/// Knobs for seeded plan generation ([`ChaosPlan::generate`]).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Horizon: every event lands at an op in `[1, ops]`.
    pub ops: u64,
    /// Permanent single-DPU deaths, drawn from the caller's victim list
    /// (the caller restricts victims so every shard keeps coverage).
    pub dpu_deaths: usize,
    /// One-shot transient launch failures.
    pub transient_launches: usize,
    /// One-shot transient transfer failures.
    pub transient_transfers: usize,
    /// Straggler windows (random socket, window within the horizon).
    pub stragglers: usize,
    /// Stragglers slow their socket by an integer factor in
    /// `[2, straggler_max_factor]`.
    pub straggler_max_factor: u64,
    /// Replica-loss events (0 disables).
    pub replica_losses: usize,
    /// Replica count the losses index into (0 disables).
    pub replicas: usize,
    /// Silent MRAM bit flips (victim DPU drawn from the victim list,
    /// address from the MRAM corruption window below). 0 disables; the
    /// corruption draws come from a domain-separated subseed, so plans
    /// with all corruption counts at 0 are byte-identical to plans
    /// generated before these knobs existed.
    pub mram_bit_flips: usize,
    /// Silent WRAM bit flips (same draw scheme, WRAM window below).
    pub wram_bit_flips: usize,
    /// In-flight transfer corruptions: one bit flipped in the landed
    /// bytes at a transfer boundary.
    pub transfer_corruptions: usize,
    /// MRAM corruption window: flip addresses are drawn uniformly from
    /// `[corrupt_mram_base, corrupt_mram_base + corrupt_mram_len)`.
    /// Defaults to the first KB of the repo-wide data base `0x10_0000`
    /// (where GEMV keeps the resident matrix).
    pub corrupt_mram_base: u32,
    pub corrupt_mram_len: u32,
    /// WRAM corruption window. Defaults to `[0xE000, 0x10000)` — WRAM
    /// the framework-built kernels never read, making default WRAM
    /// flips the *undetectable-by-construction* corruption class the
    /// integrity tests must report rather than silently pass.
    pub corrupt_wram_base: u32,
    pub corrupt_wram_len: u32,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            ops: 64,
            dpu_deaths: 2,
            transient_launches: 2,
            transient_transfers: 1,
            stragglers: 1,
            straggler_max_factor: 4,
            replica_losses: 0,
            replicas: 0,
            mram_bit_flips: 0,
            wram_bit_flips: 0,
            transfer_corruptions: 0,
            corrupt_mram_base: 0x10_0000,
            corrupt_mram_len: 0x400,
            corrupt_wram_base: 0xE000,
            corrupt_wram_len: 0x2000,
        }
    }
}

/// A schedule of [`FaultEvent`]s, sorted by activation op.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChaosPlan {
    events: Vec<FaultEvent>,
}

impl ChaosPlan {
    /// Build from an explicit event list (sorted by activation op;
    /// ties keep the given order).
    pub fn from_events(mut events: Vec<FaultEvent>) -> ChaosPlan {
        events.sort_by_key(|e| e.at());
        ChaosPlan { events }
    }

    /// Seeded generation: the same `(seed, cfg, victims)` triple always
    /// yields the same plan. Permanent deaths are drawn from `victims`
    /// only — pass the DPUs whose loss the topology can absorb (e.g.
    /// every shard's tail DPUs), so a generated plan always leaves ≥1
    /// usable DPU per shard and the keystone bit-exactness property
    /// holds.
    pub fn generate(seed: u64, cfg: &ChaosConfig, victims: &[DpuId]) -> ChaosPlan {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        let mut pool: Vec<DpuId> = victims.to_vec();
        rng.shuffle(&mut pool);
        for &dpu in pool.iter().take(cfg.dpu_deaths) {
            events.push(FaultEvent::DpuDeath { at: rng.range_u64(1, cfg.ops), dpu });
        }
        for _ in 0..cfg.transient_launches {
            events.push(FaultEvent::TransientLaunch { at: rng.range_u64(1, cfg.ops) });
        }
        for _ in 0..cfg.transient_transfers {
            events.push(FaultEvent::TransientTransfer { at: rng.range_u64(1, cfg.ops) });
        }
        for _ in 0..cfg.stragglers {
            let from = rng.range_u64(1, cfg.ops);
            events.push(FaultEvent::Straggler {
                from,
                to: from + rng.range_u64(1, cfg.ops),
                socket: rng.below(SOCKETS as u64) as usize,
                factor: rng.range_u64(2, cfg.straggler_max_factor.max(2)) as f64,
            });
        }
        if cfg.replicas > 0 {
            for _ in 0..cfg.replica_losses {
                events.push(FaultEvent::ReplicaLoss {
                    at: rng.range_u64(1, cfg.ops),
                    replica: rng.below(cfg.replicas as u64) as usize,
                });
            }
        }
        // Corruption events draw from a domain-separated subseed that
        // is created (and consumed) only when a corruption knob is
        // nonzero: pre-existing seeds keep producing byte-identical
        // plans, and the main stream above never moves. Victim DPUs
        // come from the same caller-restricted list as deaths.
        let n_corr = cfg.mram_bit_flips + cfg.wram_bit_flips + cfg.transfer_corruptions;
        if n_corr > 0 && !victims.is_empty() {
            let mut crng = Rng::new(seed ^ CORRUPTION_DOMAIN);
            for _ in 0..cfg.mram_bit_flips {
                events.push(FaultEvent::MramBitFlip {
                    at: crng.range_u64(1, cfg.ops),
                    dpu: *crng.choose(victims),
                    addr: cfg.corrupt_mram_base
                        + crng.below(u64::from(cfg.corrupt_mram_len.max(1))) as u32,
                    bit: crng.below(8) as u8,
                });
            }
            for _ in 0..cfg.wram_bit_flips {
                events.push(FaultEvent::WramBitFlip {
                    at: crng.range_u64(1, cfg.ops),
                    dpu: *crng.choose(victims),
                    addr: cfg.corrupt_wram_base
                        + crng.below(u64::from(cfg.corrupt_wram_len.max(1))) as u32,
                    bit: crng.below(8) as u8,
                });
            }
            for _ in 0..cfg.transfer_corruptions {
                events.push(FaultEvent::TransferCorruption {
                    at: crng.range_u64(1, cfg.ops),
                    dpu: *crng.choose(victims),
                    addr: cfg.corrupt_mram_base
                        + crng.below(u64::from(cfg.corrupt_mram_len.max(1))) as u32,
                    bit: crng.below(8) as u8,
                });
            }
        }
        ChaosPlan::from_events(events)
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// `(at, replica)` pairs in activation order — the serving harness
    /// consumes these (the device-plane injector ignores them).
    pub fn replica_losses(&self) -> Vec<(u64, usize)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::ReplicaLoss { at, replica } => Some((*at, *replica)),
                _ => None,
            })
            .collect()
    }

    /// The corruption events (MRAM/WRAM bit flips and transfer
    /// corruptions) in activation order — what the integrity layer must
    /// account for, one way or the other.
    pub fn corruptions(&self) -> Vec<FaultEvent> {
        self.events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    FaultEvent::MramBitFlip { .. }
                        | FaultEvent::WramBitFlip { .. }
                        | FaultEvent::TransferCorruption { .. }
                )
            })
            .cloned()
            .collect()
    }

    /// DPUs the plan kills outright via [`FaultEvent::DpuDeath`]
    /// (rank deaths are expanded against the topology at fire time).
    pub fn dead_dpus(&self) -> Vec<DpuId> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::DpuDeath { dpu, .. } => Some(*dpu),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_events_sorts_by_activation_op() {
        let plan = ChaosPlan::from_events(vec![
            FaultEvent::TransientLaunch { at: 9 },
            FaultEvent::Straggler { from: 2, to: 5, socket: 0, factor: 2.0 },
            FaultEvent::DpuDeath { at: 4, dpu: 7 },
        ]);
        let ats: Vec<u64> = plan.events().iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![2, 4, 9]);
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let victims: Vec<DpuId> = (0..32).collect();
        let cfg = ChaosConfig::default();
        let a = ChaosPlan::generate(11, &cfg, &victims);
        let b = ChaosPlan::generate(11, &cfg, &victims);
        assert_eq!(a, b, "same seed, same plan");
        let c = ChaosPlan::generate(12, &cfg, &victims);
        assert_ne!(a, c, "different seed, different plan");
    }

    #[test]
    fn generation_respects_config_counts_and_bounds() {
        let victims: Vec<DpuId> = (100..140).collect();
        let cfg = ChaosConfig {
            ops: 32,
            dpu_deaths: 3,
            transient_launches: 2,
            transient_transfers: 2,
            stragglers: 2,
            straggler_max_factor: 5,
            replica_losses: 2,
            replicas: 4,
            mram_bit_flips: 2,
            wram_bit_flips: 1,
            transfer_corruptions: 1,
            ..ChaosConfig::default()
        };
        let plan = ChaosPlan::generate(77, &cfg, &victims);
        assert_eq!(plan.len(), 3 + 2 + 2 + 2 + 2 + 2 + 1 + 1);
        assert_eq!(plan.dead_dpus().len(), 3);
        assert_eq!(plan.corruptions().len(), 4);
        for d in plan.dead_dpus() {
            assert!(victims.contains(&d), "deaths drawn from the victim list only");
        }
        for e in plan.events() {
            assert!(e.at() >= 1 && e.at() <= 32, "activation in [1, ops]: {e:?}");
            match e {
                FaultEvent::Straggler { from, to, socket, factor } => {
                    assert!(to > from);
                    assert!(*socket < SOCKETS);
                    assert!(*factor >= 2.0 && *factor <= 5.0);
                }
                FaultEvent::ReplicaLoss { replica, .. } => assert!(*replica < 4),
                FaultEvent::MramBitFlip { dpu, addr, bit, .. }
                | FaultEvent::TransferCorruption { dpu, addr, bit, .. } => {
                    assert!(victims.contains(dpu), "corruption victims from the list only");
                    let lo = cfg.corrupt_mram_base;
                    assert!((lo..lo + cfg.corrupt_mram_len).contains(addr), "{e:?}");
                    assert!(*bit < 8);
                }
                FaultEvent::WramBitFlip { dpu, addr, bit, .. } => {
                    assert!(victims.contains(dpu));
                    let lo = cfg.corrupt_wram_base;
                    assert!((lo..lo + cfg.corrupt_wram_len).contains(addr), "{e:?}");
                    assert!(*bit < 8);
                }
                _ => {}
            }
        }
        assert_eq!(plan.replica_losses().len(), 2);
    }

    /// Satellite 1 regression: corruption draws come from a
    /// domain-separated subseed, so for every committed seed a plan
    /// with all corruption knobs at zero is *byte-identical* to what
    /// `generate` produced before the knobs existed — replicated here
    /// by replaying the pre-knob draw sequence by hand — and the
    /// region knobs are inert while the counts stay zero.
    #[test]
    fn corruption_free_plans_are_stable() {
        let victims: Vec<DpuId> = (0..16).collect();
        let cfg = ChaosConfig { ops: 8, ..ChaosConfig::default() };
        for seed in [11u64, 23, 47] {
            // The pre-knob generator, draw for draw.
            let mut rng = Rng::new(seed);
            let mut events = Vec::new();
            let mut pool = victims.clone();
            rng.shuffle(&mut pool);
            for &dpu in pool.iter().take(cfg.dpu_deaths) {
                events.push(FaultEvent::DpuDeath { at: rng.range_u64(1, cfg.ops), dpu });
            }
            for _ in 0..cfg.transient_launches {
                events.push(FaultEvent::TransientLaunch { at: rng.range_u64(1, cfg.ops) });
            }
            for _ in 0..cfg.transient_transfers {
                events.push(FaultEvent::TransientTransfer { at: rng.range_u64(1, cfg.ops) });
            }
            for _ in 0..cfg.stragglers {
                let from = rng.range_u64(1, cfg.ops);
                events.push(FaultEvent::Straggler {
                    from,
                    to: from + rng.range_u64(1, cfg.ops),
                    socket: rng.below(SOCKETS as u64) as usize,
                    factor: rng.range_u64(2, cfg.straggler_max_factor.max(2)) as f64,
                });
            }
            let want = ChaosPlan::from_events(events);
            assert_eq!(
                ChaosPlan::generate(seed, &cfg, &victims),
                want,
                "seed {seed}: zero corruption knobs must not perturb the plan"
            );
            // Region knobs are inert while counts are zero.
            let moved = ChaosConfig {
                corrupt_mram_base: 0x20_0000,
                corrupt_mram_len: 8,
                corrupt_wram_base: 0,
                corrupt_wram_len: 8,
                ..cfg.clone()
            };
            assert_eq!(ChaosPlan::generate(seed, &moved, &victims), want, "seed {seed}");
        }
    }

    #[test]
    fn corruption_draws_are_seeded_and_victim_gated() {
        let cfg = ChaosConfig {
            ops: 8,
            mram_bit_flips: 2,
            transfer_corruptions: 1,
            ..ChaosConfig::default()
        };
        let victims: Vec<DpuId> = (64..80).collect();
        let a = ChaosPlan::generate(11, &cfg, &victims);
        assert_eq!(a, ChaosPlan::generate(11, &cfg, &victims), "same seed, same plan");
        assert_ne!(a, ChaosPlan::generate(23, &cfg, &victims));
        assert_eq!(a.corruptions().len(), 3);
        // No victims to corrupt → no corruption events, no subseed use.
        assert_eq!(ChaosPlan::generate(11, &cfg, &[]).corruptions().len(), 0);
    }

    #[test]
    fn deaths_capped_by_victim_list() {
        let cfg = ChaosConfig { dpu_deaths: 10, ..ChaosConfig::default() };
        let plan = ChaosPlan::generate(5, &cfg, &[3, 4]);
        assert_eq!(plan.dead_dpus().len(), 2, "cannot kill more DPUs than offered");
    }
}
