//! Fig. 13 — GEMV throughput (GOPS) on UPMEM (2551 DPUs) vs a
//! dual-socket server, INT8 (a) and INT4 (b).
//!
//! Paper targets: server ≈200 GOPS INT8 (≤220) and ≈100 GOPS INT4;
//! UPMEM optimized GEMV-V ≈650 GOPS INT8 (>3× server) and ≈1000 GOPS
//! INT4 (~10× server, 1.53× INT8 GEMV-V); GEMV-MV ≈50/100 GOPS where
//! the server wins ~4×; optimized INT8 kernel ≈3.5× the baseline
//! kernel. The "server" line uses the paper's published Kunpeng
//! envelope; this machine's own CPU GEMV is reported alongside.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::bench_support::{fleet::paper_matrix_sizes, FleetGemvModel, Scenario};
use upmem_unleashed::cpu_ref::{measure_gemv_i4, measure_gemv_i8, KUNPENG_INT4_GOPS,
    KUNPENG_INT8_GOPS};
use upmem_unleashed::kernels::gemv::GemvVariant;

fn main() {
    let (_, wall) = timed(|| {
        let mut model = FleetGemvModel::paper_fleet();
        let mut t = Table::new(
            "Fig. 13 — GEMV GOPS: UPMEM (2551 DPUs) vs dual-socket server \
             (V-pipe8: SDK-v2 async batch of 8)",
            &["n", "variant", "GEMV-V", "V-pipe8", "GEMV-MV", "baseline-V", "server(paper)"],
        );
        let mut top = (0.0, 0.0, 0.0, 0.0); // i8 V, i8 MV, i4 V, i8 baseline V
        let mut top_pipe_i8 = 0.0;
        for &n in &paper_matrix_sizes() {
            for (variant, server) in [
                (GemvVariant::I8Opt, KUNPENG_INT8_GOPS),
                (GemvVariant::I4Bsdp, KUNPENG_INT4_GOPS),
            ] {
                let v = model.evaluate(n, variant, Scenario::VectorOnly).unwrap().gops();
                let vp = model.evaluate_pipelined(n, variant, 8).unwrap().gops();
                let mv = model.evaluate(n, variant, Scenario::MatrixAndVector).unwrap().gops();
                let base_v = if variant == GemvVariant::I8Opt {
                    model
                        .evaluate(n, GemvVariant::I8Baseline, Scenario::VectorOnly)
                        .unwrap()
                        .gops()
                } else {
                    f64::NAN
                };
                if n == 262_144 {
                    if variant == GemvVariant::I8Opt {
                        top.0 = v;
                        top.1 = mv;
                        top.3 = base_v;
                        top_pipe_i8 = vp;
                    } else {
                        top.2 = v;
                    }
                }
                t.row(&[
                    n.to_string(),
                    variant.name().to_string(),
                    f1(v),
                    f1(vp),
                    f1(mv),
                    if base_v.is_nan() { "-".into() } else { f1(base_v) },
                    f1(server),
                ]);
            }
        }
        t.print();
        println!("paper targets (top size, 2551 DPUs):");
        check("INT8 GEMV-V GOPS (paper ~650)", top.0, 500.0, 900.0);
        check("INT4 GEMV-V GOPS (paper ~1000)", top.2, 800.0, 1300.0);
        check("INT4/INT8 GEMV-V (paper 1.53x)", top.2 / top.0, 1.3, 1.8);
        check("INT8 GEMV-V vs server (paper >3x)", top.0 / KUNPENG_INT8_GOPS, 3.0, 4.5);
        check("INT4 GEMV-V vs server (paper ~10x)", top.2 / KUNPENG_INT4_GOPS, 8.0, 13.0);
        check("server vs INT8 GEMV-MV (paper ~4x)", KUNPENG_INT8_GOPS / top.1, 2.5, 6.0);
        check("opt vs baseline kernel (paper 3.5x; NI-naive baseline)", top.0 / top.3, 1.8,
            4.5);
        // SDK-v2 pipelining must never lose to the synchronous path.
        check("pipelined vs sync GEMV-V (v2 async, >=1x)", top_pipe_i8 / top.0, 1.0, 2.0);

        // This machine's own CPU GEMV (context, not a paper target).
        let i8 = measure_gemv_i8(512, 4096, 3, 9);
        let i4 = measure_gemv_i4(512, 4096, 3, 9);
        println!(
            "local CPU comparator ({} threads): INT8 {:.2} GOPS, INT4 {:.2} GOPS \
             (INT4/INT8 = {:.2}, paper's server: ~0.5)",
            1,
            i8.gops,
            i4.gops,
            i4.gops / i8.gops
        );
    });
    footer("fig13", wall);
}
