//! Fig. 7 — INT32 multiplication: `__mulsi3` baseline vs decomposed
//! INT32 multiplication (DIM). Paper: DIM ≈ +16%, ≤ 26 cycles/multiply.
//!
//! A third column reports the optimizer's `mul_step` truncation pass
//! applied to the *same* `__mulsi3` stream (`+passes`): the 24-bit
//! scalar bound inlines a truncated chain at each call site (§III-C),
//! landing between the call-based baseline and DIM.

mod common;

use common::{check, footer, timed, FIG_KB};
use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::kernels::arith::{
    run_microbench, run_microbench_cfg, DType, MulImpl, Spec,
};
use upmem_unleashed::opt::PassConfig;

fn main() {
    let (_, wall) = timed(|| {
        let run = |s: Spec, tk: usize| run_microbench(s, tk, FIG_KB * 1024, 42).unwrap();
        let run_passes = |s: Spec, tk: usize| {
            run_microbench_cfg(s, &PassConfig::all(), tk, FIG_KB * 1024, 42).unwrap()
        };
        let mut t = Table::new(
            "Fig. 7 — INT32 multiplication on a single DPU (MOPS)",
            &["tasklets", "baseline", "+passes", "DIM", "DIM gain"],
        );
        let mut gain16 = 0.0;
        let mut trunc16 = 0.0;
        let mut base16 = 0.0;
        for tk in [1usize, 4, 8, 11, 16] {
            let b = run(Spec::mul(DType::I32, MulImpl::Mulsi3), tk).mops;
            let p = run_passes(Spec::mul(DType::I32, MulImpl::Mulsi3), tk).mops;
            let d = run(Spec::mul(DType::I32, MulImpl::Dim), tk).mops;
            if tk == 16 {
                gain16 = d / b;
                trunc16 = p;
                base16 = b;
            }
            t.row(&[tk.to_string(), f1(b), f1(p), f1(d), f2(d / b)]);
        }
        t.print();
        println!("paper targets:");
        check("DIM gain (paper +16%)", gain16, 1.10, 1.40);
        // Cycles per multiply for DIM: 400 MHz / MOPS.
        let d16 = run(Spec::mul(DType::I32, MulImpl::Dim), 16).mops;
        check("DIM cycles/mul (paper <=26 +loop)", 400.0 / d16, 24.0, 32.0);
        // Truncation must beat the call-based baseline (it still pays
        // the 24 mul_steps, so it cannot reach DIM).
        check("truncated __mulsi3 vs baseline (>1x)", trunc16 / base16, 1.01, 1.5);
    });
    footer("fig7", wall);
}
