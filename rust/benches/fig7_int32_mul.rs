//! Fig. 7 — INT32 multiplication: `__mulsi3` baseline vs decomposed
//! INT32 multiplication (DIM). Paper: DIM ≈ +16%, ≤ 26 cycles/multiply.

mod common;

use common::{check, footer, timed, FIG_KB};
use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec};

fn main() {
    let (_, wall) = timed(|| {
        let run = |s: Spec, tk: usize| run_microbench(s, tk, FIG_KB * 1024, 42).unwrap();
        let mut t = Table::new(
            "Fig. 7 — INT32 multiplication on a single DPU (MOPS)",
            &["tasklets", "baseline", "DIM", "DIM gain"],
        );
        let mut gain16 = 0.0;
        for tk in [1usize, 4, 8, 11, 16] {
            let b = run(Spec::mul(DType::I32, MulImpl::Mulsi3), tk).mops;
            let d = run(Spec::mul(DType::I32, MulImpl::Dim), tk).mops;
            if tk == 16 {
                gain16 = d / b;
            }
            t.row(&[tk.to_string(), f1(b), f1(d), f2(d / b)]);
        }
        t.print();
        println!("paper targets:");
        check("DIM gain (paper +16%)", gain16, 1.10, 1.40);
        // Cycles per multiply for DIM: 400 MHz / MOPS.
        let d16 = run(Spec::mul(DType::I32, MulImpl::Dim), 16).mops;
        check("DIM cycles/mul (paper <=26 +loop)", 400.0 / d16, 24.0, 32.0);
    });
    footer("fig7", wall);
}
