//! Fig. 9 — bit-serial INT4 dot product vs the native INT4-as-INT8
//! baselines, normalized to the native baseline. Paper: BSDP > 2.7×
//! baseline, 1.22× the optimized native kernel.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench, DotVariant};

fn main() {
    let (_, wall) = timed(|| {
        let elems = 128 * 1024;
        let run = |v| run_dot_microbench(v, 16, elems, 42).unwrap().mmacs;
        let base = run(DotVariant::NativeBaseline);
        let opt = run(DotVariant::NativeOptimized);
        let bsdp = run(DotVariant::Bsdp);
        let mulsi3 = run(DotVariant::NativeMulsi3);
        let mut t = Table::new(
            "Fig. 9 — INT4 dot product on a single DPU (normalized)",
            &["variant", "M MAC/s", "normalized"],
        );
        for (n, v) in [
            ("native baseline", base),
            ("native optimized", opt),
            ("BSDP", bsdp),
            ("native via __mulsi3 (extra)", mulsi3),
        ] {
            t.row(&[n.to_string(), f1(v), f2(v / base)]);
        }
        t.print();
        println!("paper targets:");
        check("BSDP / baseline (paper >2.7x)", bsdp / base, 2.7, 4.5);
        check("BSDP / optimized (paper 1.22x)", bsdp / opt, 1.1, 1.8);
        check("opt / baseline ordering", opt / base, 1.5, 3.5);
        // Signed INT4 == the same kernel cost (fully unrolled sign
        // handling — paper §IV-B). Verify via a tasklet sweep shape.
        let one = run_dot_microbench(DotVariant::Bsdp, 1, 16384, 7).unwrap().mmacs;
        let eleven = run_dot_microbench(DotVariant::Bsdp, 11, 16384 * 11, 7).unwrap().mmacs;
        check("BSDP tasklet scaling 11/1", eleven / one, 10.0, 11.5);
    });
    footer("fig9", wall);
}
