//! Fig. 11 — host↔PIM parallel transfer throughput vs allocated ranks,
//! NUMA/channel-balanced allocator vs the SDK baseline, including the
//! run-to-run variability the paper reports in §V-C (E9).
//!
//! Paper targets: peak at 4 ranks; h2p ≫ p2h; gains up to 2.9× h2p /
//! 2.3× p2h at 2–10 ranks (avg 2.4× / 1.8×), tapering to ~15% / ~10%
//! at 40 ranks; variability ≤0.3 GB/s (ours) vs 2–4 GB/s (baseline).

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::table::{f2, Table};
use upmem_unleashed::host::{AllocPolicy, DpuSet, PimSystem};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::transfer::Direction;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::util::stats::{geomean, Summary};

const BOOTS: u64 = 20;
const BYTES_PER_RANK: u64 = 32 << 20; // the paper's 32 MB blocks

/// Sample through the system's transfer engine (the SDK-v2 surface the
/// coordinator itself uses), not a bare model instance.
fn sample(sys: &PimSystem, set: &DpuSet, dir: Direction, rng: &mut Rng) -> f64 {
    let total = BYTES_PER_RANK * set.ranks.ranks.len() as u64;
    sys.engine.parallel_gbps_sampled(&set.ranks.ranks, total, dir, set.placement, rng)
}

fn main() {
    let (_, wall) = timed(|| {
        let topo = SystemTopology::paper_server();
        let mut rng = Rng::new(2026);
        let mut t = Table::new(
            "Fig. 11 — parallel transfer GB/s vs ranks (mean over 20 boots)",
            &["ranks", "h2p ours", "h2p base", "gain", "p2h ours", "p2h base", "gain"],
        );
        let mut gains_h2p_small = Vec::new();
        let mut gains_p2h_small = Vec::new();
        let mut gain_h2p_40 = 0.0;
        let mut gain_p2h_40 = 0.0;
        let mut ours_h2p_spread = 0.0f64;
        let mut base_h2p_spread = 0.0f64;
        let mut peak_by_ranks = Vec::new();
        for n in [2usize, 4, 6, 8, 10, 16, 24, 32, 40] {
            let mut oh = Vec::new();
            let mut op = Vec::new();
            let mut bh = Vec::new();
            let mut bp = Vec::new();
            for boot in 0..BOOTS {
                let mut ours = PimSystem::new(topo.clone(), AllocPolicy::NumaAware);
                let so = ours.alloc_ranks(n).unwrap();
                oh.push(sample(&ours, &so, Direction::HostToPim, &mut rng));
                op.push(sample(&ours, &so, Direction::PimToHost, &mut rng));
                let mut base = PimSystem::new(
                    topo.clone(),
                    AllocPolicy::BaselineSdk { boot_seed: boot },
                );
                let sb = base.alloc_ranks(n).unwrap();
                bh.push(sample(&base, &sb, Direction::HostToPim, &mut rng));
                bp.push(sample(&base, &sb, Direction::PimToHost, &mut rng));
            }
            let (soh, sop, sbh, sbp) =
                (Summary::of(&oh), Summary::of(&op), Summary::of(&bh), Summary::of(&bp));
            let gh = soh.mean / sbh.mean;
            let gp = sop.mean / sbp.mean;
            if n <= 10 {
                gains_h2p_small.push(gh);
                gains_p2h_small.push(gp);
            }
            if n == 40 {
                gain_h2p_40 = gh;
                gain_p2h_40 = gp;
            }
            if n == 8 {
                // Variability is measured where placement can actually
                // vary between boots (at 40 ranks the whole machine is
                // allocated and only measurement jitter remains).
                ours_h2p_spread = soh.spread();
                base_h2p_spread = sbh.spread();
            }
            if n <= 8 {
                peak_by_ranks.push((n, soh.mean));
            }
            t.row(&[
                n.to_string(),
                f2(soh.mean),
                f2(sbh.mean),
                f2(gh),
                f2(sop.mean),
                f2(sbp.mean),
                f2(gp),
            ]);
        }
        t.print();
        println!("paper targets:");
        let max_h = gains_h2p_small.iter().fold(0.0f64, |a, &b| a.max(b));
        let max_p = gains_p2h_small.iter().fold(0.0f64, |a, &b| a.max(b));
        check("h2p max gain 2-10 ranks (paper 2.9x)", max_h, 2.2, 3.2);
        check("h2p avg gain 2-10 ranks (paper 2.4x)", geomean(&gains_h2p_small), 1.8, 2.8);
        check("p2h max gain 2-10 ranks (paper 2.3x)", max_p, 1.8, 2.8);
        // Our baseline's sync-read path degrades slightly more than the
        // paper's under cross-NUMA placement, so the average lands a
        // little above the paper's 1.8x (see EXPERIMENTS.md E6).
        check("p2h avg gain 2-10 ranks (paper 1.8x)", geomean(&gains_p2h_small), 1.4, 2.5);
        check("h2p tail gain at 40 ranks (paper ~15%)", gain_h2p_40, 1.0, 1.35);
        check("p2h tail gain at 40 ranks (paper ~10%)", gain_p2h_40, 1.0, 1.3);
        // Peak at 4 ranks: throughput at 4 within 5% of 8.
        let at4 = peak_by_ranks.iter().find(|(n, _)| *n == 4).unwrap().1;
        let at8 = peak_by_ranks.iter().find(|(n, _)| *n == 8).unwrap().1;
        check("peak reached at 4 ranks (4 vs 8)", at4 / at8, 0.95, 1.05);
        // E9 variability.
        println!(
            "  run-to-run spread at 8 ranks: ours {:.2} GB/s vs baseline {:.2} GB/s \
             (paper: 0.3 vs 2-4)",
            ours_h2p_spread, base_h2p_spread
        );
        check("ours spread (paper ~0.3 GB/s)", ours_h2p_spread, 0.0, 1.2);
        check("baseline spread (paper 2-4 GB/s)", base_h2p_spread, 1.2, 6.0);
    });
    footer("fig11", wall);
}
