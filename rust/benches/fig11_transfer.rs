//! Fig. 11 — host↔PIM parallel transfer throughput vs allocated ranks,
//! NUMA/channel-balanced allocator vs the SDK baseline, including the
//! run-to-run variability the paper reports in §V-C (E9), plus the
//! data-plane **placement ablation** (PR 5): Linear vs
//! ChannelInterleaved vs NumaBalanced scatter/broadcast-tree GB/s over
//! the socket-pinned transfer workers.
//!
//! Paper targets: peak at 4 ranks; h2p ≫ p2h; gains up to 2.9× h2p /
//! 2.3× p2h at 2–10 ranks (avg 2.4× / 1.8×), tapering to ~15% / ~10%
//! at 40 ranks; variability ≤0.3 GB/s (ours) vs 2–4 GB/s (baseline).
//!
//! Machine-readable output: deterministic modeled rates (no jitter)
//! are written as schema-v2 rows to `BENCH_transfer.json`
//! (`rate` field, higher-is-better) — gated in CI by
//! `tools/check_perf_regression.py` against
//! `ci/BENCH_transfer_baseline.json` and filled into EXPERIMENTS.md
//! §Placement ablation by `tools/fill_experiments.py --transfer`.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::alloc::NumaAwareAllocator;
use upmem_unleashed::bench_support::json::{json_perf_report, WorkloadEntry};
use upmem_unleashed::bench_support::table::{f2, Table};
use upmem_unleashed::host::{AllocPolicy, DpuSet, PimSystem};
use upmem_unleashed::plane::{
    placement_rates, ChannelInterleaved, Linear, NumaBalanced, PlacementPolicy,
};
use upmem_unleashed::transfer::model::TransferModel;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::transfer::Direction;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::util::stats::{geomean, Summary};

const BOOTS: u64 = 20;
const BYTES_PER_RANK: u64 = 32 << 20; // the paper's 32 MB blocks

/// Placement-ablation fleet shape: 4 shards × 2 ranks.
const ABLATION_SHARDS: usize = 4;
const ABLATION_RANKS_PER_SHARD: usize = 2;
/// Per-shard matrix block and broadcast payload for the ablation.
const ABLATION_SHARD_BYTES: u64 = 64 << 20;
const ABLATION_X_BYTES: u64 = 4 << 20;

/// Deterministic modeled scatter + broadcast-tree rates for one boot of
/// `policy` on `topo`: place the ablation fleet, then rate it through
/// the plane's shared [`placement_rates`] model (the same helper the
/// acceptance tests pin).
fn boot_rates(topo: &SystemTopology, policy: &dyn PlacementPolicy) -> (f64, f64, f64) {
    let model = TransferModel::default();
    let mut alloc = NumaAwareAllocator::new(topo.clone());
    let p = policy.place(&mut alloc, ABLATION_SHARDS, ABLATION_RANKS_PER_SHARD).unwrap();
    placement_rates(topo, &model, &p, ABLATION_SHARD_BYTES, ABLATION_X_BYTES)
}

/// Sample through the system's transfer engine (the SDK-v2 surface the
/// coordinator itself uses), not a bare model instance.
fn sample(sys: &PimSystem, set: &DpuSet, dir: Direction, rng: &mut Rng) -> f64 {
    let total = BYTES_PER_RANK * set.ranks.ranks.len() as u64;
    sys.engine.parallel_gbps_sampled(&set.ranks.ranks, total, dir, set.placement, rng)
}

fn main() {
    let (_, wall) = timed(|| {
        let topo = SystemTopology::paper_server();
        let mut rng = Rng::new(2026);
        let mut t = Table::new(
            "Fig. 11 — parallel transfer GB/s vs ranks (mean over 20 boots)",
            &["ranks", "h2p ours", "h2p base", "gain", "p2h ours", "p2h base", "gain"],
        );
        let mut gains_h2p_small = Vec::new();
        let mut gains_p2h_small = Vec::new();
        let mut gain_h2p_40 = 0.0;
        let mut gain_p2h_40 = 0.0;
        let mut ours_h2p_spread = 0.0f64;
        let mut base_h2p_spread = 0.0f64;
        let mut peak_by_ranks = Vec::new();
        for n in [2usize, 4, 6, 8, 10, 16, 24, 32, 40] {
            let mut oh = Vec::new();
            let mut op = Vec::new();
            let mut bh = Vec::new();
            let mut bp = Vec::new();
            for boot in 0..BOOTS {
                let mut ours = PimSystem::new(topo.clone(), AllocPolicy::NumaAware);
                let so = ours.alloc_ranks(n).unwrap();
                oh.push(sample(&ours, &so, Direction::HostToPim, &mut rng));
                op.push(sample(&ours, &so, Direction::PimToHost, &mut rng));
                let mut base = PimSystem::new(
                    topo.clone(),
                    AllocPolicy::BaselineSdk { boot_seed: boot },
                );
                let sb = base.alloc_ranks(n).unwrap();
                bh.push(sample(&base, &sb, Direction::HostToPim, &mut rng));
                bp.push(sample(&base, &sb, Direction::PimToHost, &mut rng));
            }
            let (soh, sop, sbh, sbp) =
                (Summary::of(&oh), Summary::of(&op), Summary::of(&bh), Summary::of(&bp));
            let gh = soh.mean / sbh.mean;
            let gp = sop.mean / sbp.mean;
            if n <= 10 {
                gains_h2p_small.push(gh);
                gains_p2h_small.push(gp);
            }
            if n == 40 {
                gain_h2p_40 = gh;
                gain_p2h_40 = gp;
            }
            if n == 8 {
                // Variability is measured where placement can actually
                // vary between boots (at 40 ranks the whole machine is
                // allocated and only measurement jitter remains).
                ours_h2p_spread = soh.spread();
                base_h2p_spread = sbh.spread();
            }
            if n <= 8 {
                peak_by_ranks.push((n, soh.mean));
            }
            t.row(&[
                n.to_string(),
                f2(soh.mean),
                f2(sbh.mean),
                f2(gh),
                f2(sop.mean),
                f2(sbp.mean),
                f2(gp),
            ]);
        }
        t.print();
        println!("paper targets:");
        let max_h = gains_h2p_small.iter().fold(0.0f64, |a, &b| a.max(b));
        let max_p = gains_p2h_small.iter().fold(0.0f64, |a, &b| a.max(b));
        check("h2p max gain 2-10 ranks (paper 2.9x)", max_h, 2.2, 3.2);
        check("h2p avg gain 2-10 ranks (paper 2.4x)", geomean(&gains_h2p_small), 1.8, 2.8);
        check("p2h max gain 2-10 ranks (paper 2.3x)", max_p, 1.8, 2.8);
        // Our baseline's sync-read path degrades slightly more than the
        // paper's under cross-NUMA placement, so the average lands a
        // little above the paper's 1.8x (see EXPERIMENTS.md E6).
        check("p2h avg gain 2-10 ranks (paper 1.8x)", geomean(&gains_p2h_small), 1.4, 2.5);
        check("h2p tail gain at 40 ranks (paper ~15%)", gain_h2p_40, 1.0, 1.35);
        check("p2h tail gain at 40 ranks (paper ~10%)", gain_p2h_40, 1.0, 1.3);
        // Peak at 4 ranks: throughput at 4 within 5% of 8.
        let at4 = peak_by_ranks.iter().find(|(n, _)| *n == 4).unwrap().1;
        let at8 = peak_by_ranks.iter().find(|(n, _)| *n == 8).unwrap().1;
        check("peak reached at 4 ranks (4 vs 8)", at4 / at8, 0.95, 1.05);
        // E9 variability.
        println!(
            "  run-to-run spread at 8 ranks: ours {:.2} GB/s vs baseline {:.2} GB/s \
             (paper: 0.3 vs 2-4)",
            ours_h2p_spread, base_h2p_spread
        );
        check("ours spread (paper ~0.3 GB/s)", ours_h2p_spread, 0.0, 1.2);
        check("baseline spread (paper 2-4 GB/s)", base_h2p_spread, 1.2, 6.0);

        // ---- machine-readable deterministic rows (schema v2, `rate`) ----
        // No jitter: the modeled curves alone, so the CI gate against
        // ci/BENCH_transfer_baseline.json is bit-stable.
        let mut entries: Vec<WorkloadEntry> = Vec::new();
        for n in [2usize, 4, 8, 40] {
            let total = BYTES_PER_RANK * n as u64;
            let mut ours = PimSystem::new(topo.clone(), AllocPolicy::NumaAware);
            let so = ours.alloc_ranks(n).unwrap();
            let og = total as f64 / ours.push_parallel_modeled(&so, total).seconds / 1e9;
            entries.push(
                WorkloadEntry::new(format!("xfer h2p {n} ranks ours (GB/s)"), 0.0, None)
                    .with_rate(og),
            );
            let mut base_sum = 0.0;
            for boot in 0..BOOTS {
                let mut base =
                    PimSystem::new(topo.clone(), AllocPolicy::BaselineSdk { boot_seed: boot });
                let sb = base.alloc_ranks(n).unwrap();
                base_sum += total as f64 / base.push_parallel_modeled(&sb, total).seconds / 1e9;
            }
            entries.push(
                WorkloadEntry::new(format!("xfer h2p {n} ranks baseline (GB/s)"), 0.0, None)
                    .with_rate(base_sum / BOOTS as f64),
            );
        }

        // ---- data-plane placement ablation (PR 5) ------------------------
        // Every policy is rated over the same 20 boots: Linear's
        // placement varies with the udev order, the aware policies are
        // boot-invariant — the spread column *measures* that instead of
        // asserting it.
        let mut pt = Table::new(
            "Placement ablation — 4 shards x 2 ranks, modeled GB/s (mean over 20 boots)",
            &["policy", "scatter", "broadcast tree", "push+broadcast", "spread"],
        );
        let mut combined_mean = std::collections::BTreeMap::new();
        let mut combined_spread = std::collections::BTreeMap::new();
        for kind in ["linear", "channel-interleaved", "numa-balanced"] {
            let mut sc = Vec::new();
            let mut tr = Vec::new();
            let mut co = Vec::new();
            for boot in 0..BOOTS {
                let policy: Box<dyn PlacementPolicy> = match kind {
                    "linear" => Box::new(Linear { boot_seed: boot }),
                    "channel-interleaved" => Box::new(ChannelInterleaved),
                    _ => Box::new(NumaBalanced),
                };
                let (s, t, c) = boot_rates(&topo, policy.as_ref());
                sc.push(s);
                tr.push(t);
                co.push(c);
            }
            let (ssc, stre, sco) = (Summary::of(&sc), Summary::of(&tr), Summary::of(&co));
            pt.row(&[
                kind.into(),
                f2(ssc.mean),
                f2(stre.mean),
                f2(sco.mean),
                f2(sco.spread()),
            ]);
            combined_mean.insert(kind, sco.mean);
            combined_spread.insert(kind, sco.spread());
            entries.push(
                WorkloadEntry::new(format!("plane scatter 4x2 {kind} (GB/s)"), 0.0, None)
                    .with_rate(ssc.mean),
            );
            entries.push(
                WorkloadEntry::new(format!("plane broadcast-tree 4x2 {kind} (GB/s)"), 0.0, None)
                    .with_rate(stre.mean),
            );
            entries.push(
                WorkloadEntry::new(format!("plane push+broadcast 4x2 {kind} (GB/s)"), 0.0, None)
                    .with_rate(sco.mean),
            );
        }
        pt.print();
        let lin = combined_mean["linear"];
        let ci_ = combined_mean["channel-interleaved"];
        let numa = combined_mean["numa-balanced"];
        check("NumaBalanced/Linear push+broadcast gain (paper up to 2.9x)", numa / lin, 1.8, 4.5);
        check("ChannelInterleaved sits between the extremes", (ci_ - lin) / (numa - lin), 0.0, 1.0);
        check("Linear boot-to-boot spread (GB/s)", combined_spread["linear"], 0.5, 12.0);
        check(
            "NumaBalanced boot-to-boot spread (GB/s)",
            combined_spread["numa-balanced"],
            0.0,
            0.01,
        );

        let json = json_perf_report(&entries, None);
        match std::fs::write("BENCH_transfer.json", &json) {
            Ok(()) => println!("wrote BENCH_transfer.json ({} entries)", entries.len()),
            Err(e) => eprintln!("could not write BENCH_transfer.json: {e}"),
        }
    });
    footer("fig11", wall);
}
