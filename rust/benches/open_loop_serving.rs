//! §Open-loop serving — the traffic plane under committed seeds × load
//! levels.
//!
//! Replays seeded Poisson arrival plans through the deterministic
//! open-loop harness (`rust/src/traffic/`): two sharded replicas
//! behind an SLO-aware router, bounded admission queues, deadline
//! batching. Load levels are expressed against the pool's *calibrated*
//! saturation rate (one pipelined batch on the modeled clock), so
//! "0.5× / 1.0× / 2.0×" mean the same thing on every machine. A chaos
//! scenario rides along: device-fault plans on both replicas plus a
//! plan-scheduled replica loss mid-burst, mirroring the keystone test.
//!
//! Everything is threadless and modeled, so every gated row (modeled
//! req/s, goodput) is a pure function of (seed, load, tier) and CI can
//! compare it exactly across execution tiers. Shed rates and latency
//! percentiles are written as informational rows (a shed rate is
//! lower-is-better — the opposite gating direction from a rate — so it
//! is parked in the ungated field). `PERF_SMOKE=1` shrinks the request
//! stream to CI size.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::json::{json_perf_report, PerfMeta, WorkloadEntry};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::chaos::{ChaosConfig, ChaosInjector, ChaosPlan, SelfHealingCoordinator};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::dpu::default_exec_tier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::telemetry::{chrome_trace_json, trace_sink, MetricsRegistry, TraceRecorder};
use upmem_unleashed::traffic::{
    AdmissionConfig, AdmissionPolicy, ArrivalProcess, DeadlineBatcher, OpenLoopSim, SimConfig,
    TrafficConfig, TrafficPlan, TrafficReport, WorkloadMix,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

const ROWS: u32 = 128;
const COLS: u32 = 512;
const BATCH: usize = 4;
const REPLICAS: usize = 2;
/// Committed traffic seeds — CI replays exactly these.
const SEEDS: [u64; 2] = [11, 23];
/// Seed for the chaos-mid-burst scenario.
const CHAOS_SEED: u64 = 47;
/// Load levels as multiples of the pool's calibrated saturation rate.
const LOADS: [f64; 3] = [0.5, 1.0, 2.0];

fn build() -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).expect("2 shards x 1 rank");
    let map = ShardMap::new(sets, NumaBalanced.name()).expect("shard map");
    ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8)
}

fn preloaded(m: &[i8]) -> ShardedGemvCoordinator {
    let mut c = build();
    c.preload_matrix(ROWS, COLS, m).expect("preload");
    c
}

/// Modeled seconds per full pipelined batch — the saturation unit.
fn batch_seconds(m: &[i8]) -> f64 {
    let mut c = preloaded(m);
    let xs: Vec<Vec<i8>> = (0..BATCH).map(|i| vec![i as i8 + 1; COLS as usize]).collect();
    let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
    let t0 = c.sys.sync_all();
    c.gemv_pipelined(&views).expect("calibration batch");
    c.sys.sync_all() - t0
}

fn plan(seed: u64, rate_rps: f64, requests: usize, deadline_s: Option<f64>) -> TrafficPlan {
    TrafficPlan::generate(
        seed,
        &TrafficConfig {
            process: ArrivalProcess::Poisson { rate_rps },
            requests,
            deadline_s,
            mix: WorkloadMix::single(ROWS, COLS, GemvVariant::I8Opt),
        },
    )
}

fn sim_cfg(dt: f64) -> SimConfig {
    SimConfig {
        batcher: DeadlineBatcher::new(BATCH, 0.5 * dt),
        admission: AdmissionConfig { policy: AdmissionPolicy::RejectNew, queue_cap: 2 * BATCH },
        policy: Policy::SloAware,
    }
}

fn push_rows(
    entries: &mut Vec<WorkloadEntry>,
    table: &mut Table,
    scenario: &str,
    tag: &str,
    rep: &TrafficReport,
) {
    let s = rep.latency_summary();
    let (p50, p95, p99) = s.map_or((0.0, 0.0, 0.0), |s| (s.p50, s.p95, s.p99));
    table.row(&[
        scenario.into(),
        f1(rep.throughput_rps()),
        format!("{:.3}", rep.goodput()),
        format!("{:.3}", rep.metrics.shed_rate()),
        format!("{:.3}", p50 / 1e3),
        format!("{:.3}", p95 / 1e3),
        format!("{:.3}", p99 / 1e3),
    ]);
    entries.push(
        WorkloadEntry::new(format!("open-loop serving modeled req/s {tag}"), 0.0, None)
            .with_rate(rep.throughput_rps()),
    );
    entries.push(
        WorkloadEntry::new(format!("open-loop goodput (fraction) {tag}"), 0.0, None)
            .with_rate(rep.goodput()),
    );
    // Informational (ungated): shed rate is lower-is-better and the
    // percentiles are costs, not rates.
    entries.push(WorkloadEntry::new(
        format!("open-loop shed rate (fraction, informational) {tag}"),
        rep.metrics.shed_rate(),
        None,
    ));
    for (q, v) in [("p50", p50), ("p95", p95), ("p99", p99)] {
        entries.push(WorkloadEntry::new(
            format!("open-loop {q} latency (modeled ms, informational) {tag}"),
            v / 1e3,
            None,
        ));
    }
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    if smoke {
        println!("[open_loop_serving] PERF_SMOKE set: CI-sized request stream");
    }
    let requests: usize = if smoke { 12 } else { 48 };
    let (_, wall) = timed(|| {
        let m = Rng::new(4242).i8_vec((ROWS * COLS) as usize);
        let dt = batch_seconds(&m);
        let sat_pool = REPLICAS as f64 * BATCH as f64 / dt;
        println!(
            "calibration: {dt:.6} modeled s per {BATCH}-batch → pool saturation {:.1} req/s",
            sat_pool
        );
        let mut entries: Vec<WorkloadEntry> = Vec::new();
        let mut table = Table::new(
            "§Open-loop serving — seeded arrival plans × load levels",
            &[
                "scenario",
                "req/s (modeled)",
                "goodput",
                "shed rate",
                "p50 ms",
                "p95 ms",
                "p99 ms",
            ],
        );

        // Load sweep: seeded Poisson plans at fractions of saturation.
        for seed in SEEDS {
            for load in LOADS {
                let p = plan(seed, load * sat_pool, requests, None);
                let pool: Vec<Vec<ShardedGemvCoordinator>> =
                    vec![(0..REPLICAS).map(|_| preloaded(&m)).collect()];
                let mut sim = OpenLoopSim::new(sim_cfg(dt), pool);
                let rep = sim.run(&p, &[]);
                let tag = format!("[seed={seed} load={load:.1}x]");
                if load < 1.0 {
                    check(
                        &format!("seed {seed} load {load:.1}x: below saturation nothing sheds"),
                        rep.metrics.shed_rate(),
                        0.0,
                        0.0,
                    );
                    check(
                        &format!("seed {seed} load {load:.1}x: goodput is total"),
                        rep.goodput(),
                        1.0,
                        1.0,
                    );
                }
                push_rows(&mut entries, &mut table, &format!("seed={seed} {load:.1}x"), &tag, &rep);
            }
        }

        // Chaos mid-burst: device-fault plans on both replicas plus a
        // plan-scheduled replica loss, at 1.5× saturation with tight
        // deadlines — the keystone scenario, measured.
        let loss_cfg = ChaosConfig {
            ops: requests as u64,
            dpu_deaths: 0,
            transient_launches: 0,
            transient_transfers: 0,
            stragglers: 0,
            replica_losses: 1,
            replicas: REPLICAS,
            ..ChaosConfig::default()
        };
        let losses = ChaosPlan::generate(CHAOS_SEED, &loss_cfg, &[]).replica_losses();
        let replicas: Vec<SelfHealingCoordinator> = (0..REPLICAS as u64)
            .map(|r| {
                let mut c = preloaded(&m);
                let victims: Vec<usize> =
                    (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
                let ccfg = ChaosConfig { ops: 6, ..ChaosConfig::default() };
                c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(
                    CHAOS_SEED + r,
                    &ccfg,
                    &victims,
                )));
                SelfHealingCoordinator::new(c)
            })
            .collect();
        let p = plan(CHAOS_SEED, 1.5 * sat_pool, requests, Some(8.0 * dt));
        let mut sim = OpenLoopSim::new(sim_cfg(dt), vec![replicas]);
        // `PIM_TRACE`: record the chaos scenario's serving-level spans
        // (batch closes, sheds, evictions) on the modeled clock.
        // Recording never perturbs the run, so the gated rows below are
        // identical with or without it.
        let trace_path = trace_sink("BENCH_serving_trace.json");
        if trace_path.is_some() {
            sim.install_trace(TraceRecorder::new());
        }
        let rep = sim.run(&p, &losses);
        if let Some(path) = &trace_path {
            let tr = sim.take_trace().expect("recorder installed");
            match std::fs::write(path, chrome_trace_json(tr.events())) {
                Ok(()) => println!("wrote {path} ({} trace events)", tr.len()),
                Err(e) => eprintln!("could not write {path}: {e}"),
            }
            // The unified registry rides along: traffic + per-replica
            // recovery + chaos counters under stable dotted names.
            let mut reg = MetricsRegistry::new();
            reg.absorb_traffic(&rep);
            for r in 0..REPLICAS {
                let b = sim.backend(0, r);
                reg.absorb_recovery(b.metrics());
                if let Some(cj) = b.inner.sys.chaos() {
                    reg.absorb_chaos(cj.stats());
                }
            }
            let mpath = "BENCH_serving_metrics.json";
            match std::fs::write(mpath, reg.to_json()) {
                Ok(()) => println!("wrote {mpath} ({} metrics)", reg.len()),
                Err(e) => eprintln!("could not write {mpath}: {e}"),
            }
        }
        check(
            "chaos mid-burst: admitted traffic still serves",
            if rep.served.is_empty() { 0.0 } else { 1.0 },
            1.0,
            1.0,
        );
        check(
            "chaos mid-burst: every request served or typed-shed",
            (rep.served.len() + rep.rejections.len() + rep.failed.len()) as f64,
            requests as f64,
            requests as f64,
        );
        push_rows(
            &mut entries,
            &mut table,
            "chaos mid-burst 1.5x",
            &format!("[seed={CHAOS_SEED} chaos]"),
            &rep,
        );

        table.print();

        let meta = PerfMeta {
            exec_tier: default_exec_tier().name().to_string(),
            smoke,
            launch_workers: PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware)
                .launch_workers(),
        };
        let json = json_perf_report(&entries, Some(&meta));
        match std::fs::write("BENCH_serving_openloop.json", &json) {
            Ok(()) => println!("wrote BENCH_serving_openloop.json ({} entries)", entries.len()),
            Err(e) => eprintln!("could not write BENCH_serving_openloop.json: {e}"),
        }
    });
    footer("open_loop_serving", wall);
}
