//! Fig. 8 — peak arithmetic performance with `#pragma unroll` (auto /
//! x64 / x128). Paper: INT32 ADD doubles to 133 MOPS; INT8 ADD / MUL NI
//! gain ~67% to 133; NI×4 +30%, NI×8 +16%; aggressive unrolling can
//! overfill the 24 KB IRAM ("linker error") — reproduced as `IRAM!`.

mod common;

use common::{check, footer, timed, FIG_KB};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::kernels::arith::{emit_microbench, run_microbench, DType, MulImpl, Spec,
    Unroll};

fn mops(spec: Spec) -> Option<f64> {
    match run_microbench(spec, 16, FIG_KB * 1024, 42) {
        Ok(o) => Some(o.mops),
        Err(upmem_unleashed::Error::IramOverflow { .. }) => None,
        Err(e) => panic!("{}: {e}", spec.name()),
    }
}

fn main() {
    let (_, wall) = timed(|| {
        let specs: Vec<(&str, Spec)> = vec![
            ("INT8 ADD", Spec::add(DType::I8)),
            ("INT8 MUL NI", Spec::mul(DType::I8, MulImpl::Native)),
            ("INT8 MUL NIx4", Spec::mul(DType::I8, MulImpl::NativeX4)),
            ("INT8 MUL NIx8", Spec::mul(DType::I8, MulImpl::NativeX8)),
            ("INT32 ADD", Spec::add(DType::I32)),
            ("INT32 MUL baseline", Spec::mul(DType::I32, MulImpl::Mulsi3)),
            ("INT32 MUL DIM", Spec::mul(DType::I32, MulImpl::Dim)),
        ];
        let mut t = Table::new(
            "Fig. 8 — peak MOPS with unrolling (16 tasklets)",
            &["variant", "none", "auto", "x64", "x128", "best gain"],
        );
        for (name, spec) in &specs {
            let cells: Vec<Option<f64>> = [Unroll::No, Unroll::Auto, Unroll::X64, Unroll::X128]
                .into_iter()
                .map(|u| mops(spec.with_unroll(u)))
                .collect();
            let base = cells[0].unwrap();
            let best = cells.iter().flatten().fold(0.0f64, |a, &b| a.max(b));
            let fmt = |c: &Option<f64>| c.map(f1).unwrap_or_else(|| "IRAM!".into());
            t.row(&[
                name.to_string(),
                fmt(&cells[0]),
                fmt(&cells[1]),
                fmt(&cells[2]),
                fmt(&cells[3]),
                format!("{:.2}x", best / base),
            ]);
        }
        t.print();
        println!("(IRAM! = >24 KB of instructions — the paper's unroll linker error)");

        println!("paper targets:");
        let g = |s: Spec, u| mops(s.with_unroll(u)).unwrap() / mops(s).unwrap();
        check("INT32 ADD x64 gain (paper 2x)", g(Spec::add(DType::I32), Unroll::X64), 1.85, 2.1);
        check("INT8 ADD x64 gain (paper +67%)", g(Spec::add(DType::I8), Unroll::X64), 1.55, 1.75);
        check(
            "INT8 MUL NI x64 gain (paper +67%)",
            g(Spec::mul(DType::I8, MulImpl::Native), Unroll::X64),
            1.55,
            1.75,
        );
        check(
            "NIx4 x64 gain (paper +30%)",
            g(Spec::mul(DType::I8, MulImpl::NativeX4), Unroll::X64),
            1.1,
            1.4,
        );
        check(
            "NIx8 x64 gain (paper +16%)",
            g(Spec::mul(DType::I8, MulImpl::NativeX8), Unroll::X64),
            1.05,
            1.3,
        );
        let unrolled_adds = (
            mops(Spec::add(DType::I8).with_unroll(Unroll::X64)).unwrap(),
            mops(Spec::add(DType::I32).with_unroll(Unroll::X64)).unwrap(),
        );
        check("INT8 ADD unrolled (paper 133)", unrolled_adds.0, 128.0, 138.0);
        check("INT32 ADD unrolled (paper 133)", unrolled_adds.1, 128.0, 138.0);
        // Paper: the INT8-vs-INT32 MUL gap grows from 2.4x to >10x.
        let best_i8 = mops(Spec::mul(DType::I8, MulImpl::NativeX8).with_unroll(Unroll::X64))
            .unwrap();
        let best_i32 = mops(Spec::mul(DType::I32, MulImpl::Dim).with_unroll(Unroll::X128))
            .unwrap();
        check("INT8/INT32 MUL gap after opt (paper >10x)", best_i8 / best_i32, 9.0, 14.0);
        // DIM at auto unroll must overflow IRAM (exercised path).
        let dim_auto = emit_microbench(Spec::mul(DType::I32, MulImpl::Dim).with_unroll(
            Unroll::Auto,
        ));
        let overflow = match dim_auto {
            Ok(p) => !p.fits_iram(),
            Err(upmem_unleashed::Error::IramOverflow { .. }) => true,
            Err(_) => false,
        };
        println!("  {} DIM auto-unroll IRAM overflow reproduced", if overflow { "PASS " } else { "DRIFT" });
    });
    footer("fig8", wall);
}
