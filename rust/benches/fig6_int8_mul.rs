//! Fig. 6 — INT8 multiplication: baseline (`__mulsi3`) vs native
//! instruction (NI) vs 32-/64-bit block loads (NI×4 / NI×8), with INT8
//! ADD for reference. Paper: NI ≈ ADD; NI×8 ≈ +80% over NI ≈ 5× baseline.

mod common;

use common::{check, footer, timed, FIG_KB};
use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec};

fn main() {
    let (_, wall) = timed(|| {
        let run = |s: Spec| run_microbench(s, 16, FIG_KB * 1024, 42).unwrap().mops;
        let base = run(Spec::mul(DType::I8, MulImpl::Mulsi3));
        let ni = run(Spec::mul(DType::I8, MulImpl::Native));
        let nix4 = run(Spec::mul(DType::I8, MulImpl::NativeX4));
        let nix8 = run(Spec::mul(DType::I8, MulImpl::NativeX8));
        let add = run(Spec::add(DType::I8));
        let mut t = Table::new(
            "Fig. 6 — INT8 multiplication on a single DPU (16 tasklets)",
            &["variant", "MOPS", "vs baseline"],
        );
        for (n, v) in [
            ("baseline (__mulsi3)", base),
            ("NI", ni),
            ("NIx4", nix4),
            ("NIx8", nix8),
            ("INT8 ADD (ref)", add),
        ] {
            t.row(&[n.to_string(), f1(v), f2(v / base)]);
        }
        t.print();
        println!("paper targets:");
        check("NI == ADD (ratio)", ni / add, 0.97, 1.03);
        check("NIx8 / NI (paper +80%)", nix8 / ni, 1.6, 2.1);
        check("NIx8 / baseline (paper ~5x)", nix8 / base, 4.0, 6.0);
        check("NIx4 between NI and NIx8", nix4, ni, nix8);
    });
    footer("fig6", wall);
}
