#![allow(dead_code)]
//! Shared helpers for the figure benches (criterion is unavailable in
//! the offline crate cache; benches are `harness = false` binaries that
//! time their workloads and print the same rows/series the paper's
//! figures plot, with the paper's expected values alongside).

use std::time::Instant;

/// Benchmark buffer size: 176 KB divides evenly across 1/2/4/8/11/16
/// tasklets, keeping per-tasklet load uniform.
pub const FIG_KB: u32 = 176;

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Print the standard bench footer.
pub fn footer(name: &str, wall: f64) {
    println!("[{name}] done in {wall:.2}s host wall time\n");
}

/// Check a measured value against the paper's expectation and print a
/// PASS/DRIFT marker (shape reproduction, not absolute equality).
pub fn check(label: &str, measured: f64, lo: f64, hi: f64) -> bool {
    let ok = (lo..=hi).contains(&measured);
    println!(
        "  {} {label}: measured {measured:.2} (expected {lo:.2}..{hi:.2})",
        if ok { "PASS " } else { "DRIFT" }
    );
    ok
}
