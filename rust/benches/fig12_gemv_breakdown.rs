//! Fig. 12 — GEMV compute time vs data transfer time on 2551 DPUs,
//! GEMV-MV (matrix + vector moved) vs GEMV-V (matrix preloaded), for
//! INT8 (a) and INT4 BSDP (b), matrix 256 MB – 64 GB.
//!
//! Paper targets: in GEMV-MV the transfer dominates ~10:1 regardless of
//! size; in GEMV-V compute dominates strongly (57× at the top end) and
//! the 2–7 ms vector transfer becomes a fixed launch overhead.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::table::{f2, human_bytes, Table};
use upmem_unleashed::bench_support::{fleet::paper_matrix_sizes, FleetGemvModel, Scenario};
use upmem_unleashed::kernels::gemv::GemvVariant;

fn main() {
    let (_, wall) = timed(|| {
        let mut model = FleetGemvModel::paper_fleet();
        let mut t = Table::new(
            "Fig. 12 — GEMV compute vs transfer on 2551 DPUs (seconds)",
            &["matrix", "variant", "scenario", "compute_s", "transfer_s", "xfer/comp"],
        );
        let mut mv_ratios_i8 = Vec::new();
        let mut v_ratio_top_i8 = 0.0;
        let mut v_vector_ms_top = 0.0;
        for &n in &paper_matrix_sizes() {
            for variant in [GemvVariant::I8Opt, GemvVariant::I4Bsdp] {
                for scenario in [Scenario::MatrixAndVector, Scenario::VectorOnly] {
                    let p = model.evaluate(n, variant, scenario).unwrap();
                    t.row(&[
                        human_bytes(p.matrix_bytes()),
                        variant.name().to_string(),
                        match scenario {
                            Scenario::MatrixAndVector => "GEMV-MV".into(),
                            Scenario::VectorOnly => "GEMV-V".to_string(),
                        },
                        format!("{:.4}", p.compute_s),
                        format!("{:.4}", p.transfer_s()),
                        f2(p.transfer_s() / p.compute_s),
                    ]);
                    if variant == GemvVariant::I8Opt {
                        match scenario {
                            Scenario::MatrixAndVector => {
                                mv_ratios_i8.push(p.transfer_s() / p.compute_s)
                            }
                            Scenario::VectorOnly if n == 262_144 => {
                                v_ratio_top_i8 = p.compute_s / p.transfer_s();
                                v_vector_ms_top = (p.vector_s + p.gather_s) * 1e3;
                            }
                            _ => {}
                        }
                    }
                }
            }
        }
        t.print();
        println!("paper targets:");
        let mv_min = mv_ratios_i8.iter().cloned().fold(f64::MAX, f64::min);
        let mv_max = mv_ratios_i8.iter().cloned().fold(0.0f64, f64::max);
        check("GEMV-MV transfer/compute min (paper ~10)", mv_min, 5.0, 20.0);
        check("GEMV-MV transfer/compute max (paper ~10)", mv_max, 5.0, 25.0);
        check("GEMV-V compute/transfer at top size (paper 57x@128GB)", v_ratio_top_i8, 20.0,
            90.0);
        check("GEMV-V vector+gather ms (paper 2-7ms)", v_vector_ms_top, 1.5, 8.0);
        // SDK-v2 async pipelining: how much of the GEMV-V transfer a
        // batch of 8 hides under compute (not a paper figure — the v2
        // host API's contribution on top of it).
        let pipe = model.evaluate_pipelined(262_144, GemvVariant::I8Opt, 8).unwrap();
        let serial = pipe.total_s() + pipe.overlap_s;
        println!(
            "  SDK-v2 pipelined GEMV-V (batch 8): {:.4}s wall vs {:.4}s serial \
             ({:.1}% of transfer hidden under compute)",
            pipe.total_s(),
            serial,
            100.0 * pipe.overlap_s
                / (pipe.vector_s + pipe.gather_s).max(f64::MIN_POSITIVE)
        );
    });
    footer("fig12", wall);
}
