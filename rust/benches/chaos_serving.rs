//! §Chaos — self-healing serving under deterministic fault plans.
//!
//! Drives the sharded serving stack (rust/src/plane/ behind
//! rust/src/chaos/) through seeded [`ChaosPlan`]s and measures what
//! robustness costs: modeled req/s fault-free vs under faults, goodput
//! (fraction of requests served bit-identical to the fault-free run —
//! the keystone says 1.0 whenever every shard keeps ≥1 usable DPU),
//! and recovery latency on the modeled clock. A replica-loss scenario
//! rides along: two replicas behind the router, a plan-scheduled loss,
//! traffic re-routed to the survivor with zero wrong answers.
//!
//! Everything here is threadless and deterministic — coordinators are
//! driven directly (no `GemvServer` worker threads), so every rate row
//! in `BENCH_serving.json` is a pure function of (seed, shape, tier)
//! and CI can gate it exactly across execution tiers
//! (`tools/check_perf_regression.py` vs `ci/BENCH_serving_baseline.json`).
//! `PERF_SMOKE=1` shrinks the request count to CI size.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::json::{json_perf_report, PerfMeta, WorkloadEntry};
use upmem_unleashed::bench_support::table::{f1, ratio, Table};
use upmem_unleashed::chaos::{ChaosConfig, ChaosInjector, ChaosPlan, SelfHealingCoordinator};
use upmem_unleashed::coordinator::router::{Policy, Router};
use upmem_unleashed::dpu::default_exec_tier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

const ROWS: u32 = 256;
const COLS: u32 = 1024;
const BATCH: usize = 4;
/// Committed chaos seeds — CI replays exactly these.
const SEEDS: [u64; 3] = [11, 23, 47];

fn build() -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).expect("2 shards x 1 rank");
    let map = ShardMap::new(sets, NumaBalanced.name()).expect("shard map");
    ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8)
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    if smoke {
        println!("[chaos_serving] PERF_SMOKE set: CI-sized request stream");
    }
    let requests: usize = if smoke { 12 } else { 48 };
    let (_, wall) = timed(|| {
        let mut rng = Rng::new(4242);
        let m = rng.i8_vec((ROWS * COLS) as usize);
        let xs: Vec<Vec<i8>> = (0..requests).map(|_| rng.i8_vec(COLS as usize)).collect();
        let mut entries: Vec<WorkloadEntry> = Vec::new();
        let mut table = Table::new(
            "§Chaos — self-healing serving under deterministic fault plans",
            &["scenario", "req/s (modeled)", "goodput", "quarantines", "retries", "recovery s"],
        );

        // Fault-free reference: the same request stream, no injector.
        let mut c = build();
        c.preload_matrix(ROWS, COLS, &m).expect("preload");
        let t0 = c.sys.modeled_now();
        let mut ys_free: Vec<Vec<i32>> = Vec::with_capacity(requests);
        for chunk in xs.chunks(BATCH) {
            let views: Vec<&[i8]> = chunk.iter().map(|v| v.as_slice()).collect();
            let (ys, _) = c.gemv_pipelined(&views).expect("fault-free gemv");
            ys_free.extend(ys);
        }
        let free_s = c.sys.sync_all() - t0;
        let free_reqps = requests as f64 / free_s;
        table.row(&[
            "fault-free".into(),
            f1(free_reqps),
            "1.000".into(),
            "0".into(),
            "0".into(),
            "0.0000".into(),
        ]);
        entries.push(
            WorkloadEntry::new("chaos serving modeled req/s [fault-free]", 0.0, None)
                .with_rate(free_reqps),
        );

        // Seeded fault runs: deaths + transients + a straggler window,
        // victims drawn so every shard keeps coverage (the keystone's
        // precondition — rust/tests/chaos_recovery.rs pins the rest).
        for seed in SEEDS {
            let mut c = build();
            c.preload_matrix(ROWS, COLS, &m).expect("preload");
            let victims: Vec<usize> =
                (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
            let cfg = ChaosConfig { ops: 16, ..ChaosConfig::default() };
            c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(seed, &cfg, &victims)));
            let mut sh = SelfHealingCoordinator::new(c);
            let t0 = sh.inner.sys.modeled_now();
            let mut ys: Vec<Vec<i32>> = Vec::with_capacity(requests);
            for chunk in xs.chunks(BATCH) {
                let views: Vec<&[i8]> = chunk.iter().map(|v| v.as_slice()).collect();
                let (batch, _) = sh.gemv_recovered(&views).expect("self-healing serve");
                ys.extend(batch);
            }
            let dur = sh.inner.sys.sync_all() - t0;
            let reqps = requests as f64 / dur;
            let exact = ys.iter().zip(&ys_free).filter(|(a, b)| a == b).count();
            let goodput = exact as f64 / requests as f64;
            let mx = sh.metrics();
            check(
                &format!("seed {seed}: goodput — every request bit-identical to fault-free"),
                goodput,
                1.0,
                1.0,
            );
            check(
                &format!("seed {seed}: faults cost throughput (fault-free / faulted req/s)"),
                free_reqps / reqps,
                1.0,
                1e9,
            );
            table.row(&[
                format!("seeded faults [seed={seed}]"),
                f1(reqps),
                format!("{goodput:.3}"),
                mx.quarantined.len().to_string(),
                mx.retries.to_string(),
                format!("{:.4}", mx.recovery_s),
            ]);
            entries.push(
                WorkloadEntry::new(format!("chaos serving modeled req/s [seed={seed}]"), 0.0, None)
                    .with_rate(reqps),
            );
            entries.push(
                WorkloadEntry::new(
                    format!("chaos goodput under faults (fraction) [seed={seed}]"),
                    0.0,
                    None,
                )
                .with_rate(goodput),
            );
            // Informational (ungated: host-independent but a cost, not a
            // rate): total modeled seconds spent inside recovery.
            entries.push(WorkloadEntry::new(
                format!("chaos recovery latency (modeled s, informational) [seed={seed}]"),
                mx.recovery_s,
                None,
            ));
        }

        // Replica loss: two replicas behind the router; the plan
        // schedules a loss (interpreted at batch granularity), the
        // survivor absorbs the rest of the stream exactly.
        let n_batches = xs.chunks(BATCH).count();
        let cfg = ChaosConfig {
            ops: n_batches as u64,
            dpu_deaths: 0,
            transient_launches: 0,
            transient_transfers: 0,
            stragglers: 0,
            replica_losses: 1,
            replicas: 2,
            ..ChaosConfig::default()
        };
        let losses = ChaosPlan::generate(SEEDS[0], &cfg, &[]).replica_losses();
        let mut reps: Vec<ShardedGemvCoordinator> = (0..2)
            .map(|_| {
                let mut c = build();
                c.preload_matrix(ROWS, COLS, &m).expect("replica preload");
                c
            })
            .collect();
        let mut router = Router::new(2, Policy::RoundRobin);
        let mut ys: Vec<Vec<i32>> = Vec::with_capacity(requests);
        for (i, chunk) in xs.chunks(BATCH).enumerate() {
            for &(at, r) in &losses {
                if at as usize <= i + 1 && !router.is_evicted(r) {
                    router.evict(r);
                    println!("  replica {r} lost before batch {} (plan op {at})", i + 1);
                }
            }
            let r = router.try_dispatch().expect("a survivor remains");
            let views: Vec<&[i8]> = chunk.iter().map(|v| v.as_slice()).collect();
            let (batch, _) = reps[r].gemv_pipelined(&views).expect("replica serve");
            ys.extend(batch);
            router.complete(r);
        }
        let exact = ys.iter().zip(&ys_free).filter(|(a, b)| a == b).count();
        let goodput = exact as f64 / requests as f64;
        check("replica loss: goodput through the surviving replica", goodput, 1.0, 1.0);
        println!(
            "  replica dispatch split: {} / {} batches (evicted replica serves nothing \
             after its loss)",
            router.dispatched(0),
            router.dispatched(1)
        );
        table.row(&[
            "replica loss (2 replicas, router)".into(),
            "—".into(),
            format!("{goodput:.3}"),
            "0".into(),
            "0".into(),
            "0.0000".into(),
        ]);
        entries.push(
            WorkloadEntry::new("chaos replica-loss goodput (fraction)", 0.0, None)
                .with_rate(goodput),
        );

        table.print();
        println!(
            "fault-free {:.1} req/s; robustness overhead is visible in the per-seed rows \
             ({} of throughput is the worst committed seed)",
            free_reqps,
            ratio(
                entries
                    .iter()
                    .filter(|e| e.name.starts_with("chaos serving modeled req/s [seed"))
                    .filter_map(|e| e.rate)
                    .fold(f64::INFINITY, f64::min)
                    / free_reqps
            )
        );

        let meta = PerfMeta {
            exec_tier: default_exec_tier().name().to_string(),
            smoke,
            launch_workers: PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware)
                .launch_workers(),
        };
        let json = json_perf_report(&entries, Some(&meta));
        match std::fs::write("BENCH_serving.json", &json) {
            Ok(()) => println!("wrote BENCH_serving.json ({} entries)", entries.len()),
            Err(e) => eprintln!("could not write BENCH_serving.json: {e}"),
        }
    });
    footer("chaos_serving", wall);
}
