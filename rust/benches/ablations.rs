//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **transfer granularity** — why the paper moves data in "large,
//!    32 MB blocks, for optimal performance": per-transfer software
//!    overhead vs block size;
//! 2. **channel balance** — intermediate placements between the SDK
//!    baseline (1 channel) and the full extension (all channels): how
//!    much of the §V gain comes from channel spreading vs NUMA
//!    spreading;
//! 3. **serving batch size** — amortizing the modeled 2 ms kernel
//!    launch overhead (§VI-B) over request batches.

mod common;

use common::{footer, timed};
use std::time::Duration;
use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::coordinator::{Batcher, GemvCoordinator, GemvServer};
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::transfer::model::BufferPlacement;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::transfer::{Direction, TransferModel};
use upmem_unleashed::util::rng::Rng;

fn ablate_transfer_granularity(topo: &SystemTopology, model: &TransferModel) {
    let mut t = Table::new(
        "Ablation 1 — transfer block size (4 balanced ranks, h2p GB/s)",
        &["block/rank", "GB/s", "vs 32 MB"],
    );
    let ranks = [0usize, 4, 20, 24]; // 4 channels, 2 sockets
    // Move a fixed 128 MB-per-rank budget as a sequence of `mb`-MB
    // parallel transfers; each transfer pays the fixed software
    // overhead once.
    let at = |mb: u64| {
        let block_bytes = mb * (1 << 20) * ranks.len() as u64;
        let per_block = model.parallel_seconds(topo, &ranks, block_bytes,
            Direction::HostToPim, BufferPlacement::PerSocket);
        let reps = 128 / mb;
        let total = block_bytes * reps;
        total as f64 / (per_block * reps as f64) / 1e9
    };
    let base = at(32);
    for mb in [1u64, 4, 8, 16, 32, 64] {
        let g = at(mb);
        t.row(&[format!("{mb} MB"), f2(g), f2(g / base)]);
    }
    t.print();
    println!("  (small blocks pay the fixed per-transfer overhead repeatedly)");
}

fn ablate_channel_balance(topo: &SystemTopology, model: &TransferModel) {
    let mut t = Table::new(
        "Ablation 2 — where the §V gain comes from (8 ranks, 32 MB/rank, h2p)",
        &["placement", "GB/s", "vs baseline"],
    );
    let bytes = 8 * 32 * (1 << 20) as u64;
    let cases: Vec<(&str, Vec<usize>, BufferPlacement)> = vec![
        // SDK-style: 2 channels of one socket (4 DIMMs), node-0 buffer.
        ("baseline: 2 channels, 1 socket", (0..8).collect(), BufferPlacement::Node(0)),
        // Spread channels but stay on one socket.
        (
            "channel-spread, 1 socket",
            vec![0, 1, 4, 5, 8, 9, 12, 16],
            BufferPlacement::Node(0),
        ),
        // Both sockets but channel-packed (one channel per socket).
        (
            "1 channel/socket, both sockets",
            vec![0, 1, 2, 3, 20, 21, 22, 23],
            BufferPlacement::PerSocket,
        ),
        // The full extension: balanced channels + NUMA-local buffers.
        (
            "balanced channels + per-socket buffers",
            vec![0, 4, 8, 12, 20, 24, 28, 32],
            BufferPlacement::PerSocket,
        ),
    ];
    let mut base = 0.0;
    for (name, ranks, placement) in cases {
        let s = model.parallel_seconds(topo, &ranks, bytes, Direction::HostToPim, placement);
        let g = bytes as f64 / s / 1e9;
        if base == 0.0 {
            base = g;
        }
        t.row(&[name.to_string(), f2(g), f2(g / base)]);
    }
    t.print();
    println!(
        "  (channel spreading alone is transpose-bound — no gain; NUMA spreading\n   \
         alone gives ~1.4x; only the combination reaches the ~2x of §V-C)"
    );
}

fn ablate_batch_size() {
    let mut t = Table::new(
        "Ablation 3 — serving batch size (GEMV-V, 128 DPUs, modeled device time; \
         batches run through the SDK-v2 pipelined path)",
        &["max_batch", "req/s (device)", "mean batch"],
    );
    for max_batch in [1usize, 2, 4, 8] {
        let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
        let set = sys.alloc_ranks(2).unwrap();
        let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 16);
        let mut rng = Rng::new(5);
        let (rows, cols) = (256u32, 1024u32);
        let m = rng.i8_vec((rows * cols) as usize);
        c.preload_matrix(rows, cols, &m).unwrap();
        let (server, client) =
            GemvServer::start(c, Batcher::new(max_batch, Duration::from_millis(2)));
        let rxs: Vec<_> = (0..16).map(|_| client.submit(rng.i8_vec(cols as usize))).collect();
        for rx in rxs {
            rx.recv().unwrap().y.unwrap();
        }
        let (_, metrics) = server.shutdown();
        t.row(&[
            max_batch.to_string(),
            f1(metrics.requests as f64 / metrics.device_seconds),
            f2(metrics.mean_batch_size()),
        ]);
    }
    t.print();
    println!(
        "  (each request is still its own kernel launch, but the SDK-v2 server\n   \
         pipelines every batch: request k+1's vector broadcast rides the rank\n   \
         bus while request k computes, so device req/s now *rises* with the\n   \
         batch size instead of being flat as it was with the v1 synchronous\n   \
         API. Merging a batch into one multi-vector launch (GEMM) remains the\n   \
         §IV-B extension the paper leaves to future work)"
    );
}

fn main() {
    let (_, wall) = timed(|| {
        let topo = SystemTopology::paper_server();
        let model = TransferModel::default();
        ablate_transfer_granularity(&topo, &model);
        ablate_channel_balance(&topo, &model);
        ablate_batch_size();
    });
    footer("ablations", wall);
}
