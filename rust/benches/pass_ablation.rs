//! Pass ablation — per-pass modeled-cycle deltas for the assembly
//! optimizer (`rust/src/opt/`), the measurable form of the paper's
//! §III/§IV/§VI hand edits. For each workload it reports:
//!
//! * **naive** — the compiler-shaped stream (`PassConfig::none()`);
//! * **all-on** — every pass (`PassConfig::all()`, DMA double-buffering
//!   included where the kernel supports it);
//! * one **ablation column per pass** — all passes on except that one;
//!   the printed delta is the cycles that pass saves on top of the
//!   others (0 means the pass has no work on that kernel, which is
//!   expected: e.g. shift-add fusion only fires on BSDP bodies).
//!
//! It also prints the `PassStats` transformation counts (fused jumps,
//! elided mul_steps, unrolled copies, removed dead code) and a
//! markdown-pasteable table for EXPERIMENTS.md §Pass ablation.
//! `PERF_SMOKE=1` shrinks workloads to CI size; modeled cycles stay
//! deterministic at any size.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::kernels::arith::{
    emit_microbench_with, run_microbench_cfg, DType, MulImpl, Spec,
};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench_cfg, DotVariant};
use upmem_unleashed::kernels::gemv::{run_gemv_dpu_with_cfg, GemvShape, GemvVariant};
use upmem_unleashed::kernels::reduce::run_reduce_cfg;
use upmem_unleashed::opt::{optimize, Pass, PassConfig, ALL_PASSES};
use upmem_unleashed::util::rng::Rng;

#[derive(Clone, Copy)]
enum Workload {
    Arith(Spec, usize, u32),
    Dot(DotVariant, usize, usize),
    Gemv(GemvVariant, usize, GemvShape),
    /// Framework-built PrIM reduction (tasklets, elements): the
    /// framework's chunk loops carry the unroll markers and dbuf
    /// streams, so the same pass matrix applies to generated code.
    Reduce(usize, usize),
}

impl Workload {
    /// Modeled cycles under `cfg`. The runners verify outputs against
    /// the host reference, so every ablation point is also a
    /// correctness check on the pass subset.
    fn cycles(&self, cfg: &PassConfig) -> u64 {
        match *self {
            Workload::Arith(spec, t, bytes) => {
                run_microbench_cfg(spec, cfg, t, bytes, 42).expect("verifies").launch.cycles
            }
            Workload::Dot(v, t, elems) => {
                run_dot_microbench_cfg(v, cfg, t, elems, 42).expect("verifies").launch.cycles
            }
            Workload::Gemv(v, t, shape) => {
                let mut rng = Rng::new(42);
                let (m, x) = match v {
                    GemvVariant::I4Bsdp => (
                        rng.i4_vec((shape.rows * shape.cols) as usize),
                        rng.i4_vec(shape.cols as usize),
                    ),
                    _ => (
                        rng.i8_vec((shape.rows * shape.cols) as usize),
                        rng.i8_vec(shape.cols as usize),
                    ),
                };
                run_gemv_dpu_with_cfg(v, cfg, shape, t, &m, &x).expect("verifies").1.cycles
            }
            Workload::Reduce(t, n) => {
                let mut rng = Rng::new(42);
                let data = rng.i32_vec(n);
                run_reduce_cfg(cfg, t, &data).expect("verifies").launch.cycles
            }
        }
    }
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    let (_, wall) = timed(|| {
        let arith_bytes: u32 = if smoke { 8 * 1024 } else { 64 * 1024 };
        let dot_elems: usize = if smoke { 8 * 1024 } else { 64 * 1024 };
        let gemv_rows: u32 = if smoke { 8 } else { 32 };
        // GEMV runs at 8 tasklets so the DMA double-buffering column is
        // measurable (the dbuf layout caps at 8; at ≥11 the revolver
        // scheduler hides DMA stalls anyway).
        let workloads: Vec<(&str, Workload)> = vec![
            (
                "INT8 MUL (__mulsi3 stream), 16T",
                Workload::Arith(Spec::mul(DType::I8, MulImpl::Mulsi3), 16, arith_bytes),
            ),
            (
                "INT32 MUL (__mulsi3 stream), 16T",
                Workload::Arith(Spec::mul(DType::I32, MulImpl::Mulsi3), 16, arith_bytes),
            ),
            (
                "INT32 ADD (counter latch), 16T",
                Workload::Arith(Spec::add(DType::I32), 16, arith_bytes),
            ),
            ("BSDP dot, 16T", Workload::Dot(DotVariant::Bsdp, 16, dot_elems)),
            ("PrIM reduce (framework), 16T", Workload::Reduce(16, dot_elems)),
            (
                "INT8 GEMV opt, 8T",
                Workload::Gemv(GemvVariant::I8Opt, 8, GemvShape { rows: gemv_rows, cols: 2048 }),
            ),
            (
                "INT4 GEMV BSDP, 8T",
                Workload::Gemv(GemvVariant::I4Bsdp, 8, GemvShape { rows: gemv_rows, cols: 4096 }),
            ),
        ];

        let mut header =
            vec!["workload".to_string(), "naive".into(), "all-on".into(), "gain".into()];
        for pass in ALL_PASSES {
            header.push(format!("Δ -{}", pass.name()));
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = upmem_unleashed::bench_support::table::Table::new(
            "Pass ablation — modeled cycles (Δ = extra cycles when that pass is disabled)",
            &header_refs,
        );
        let mut md = String::from(
            "| workload | naive | all-on | gain | ".to_string()
                + &ALL_PASSES.map(|p| format!("Δ -{}", p.name())).join(" | ")
                + " |\n",
        );
        md.push_str(&format!("|---|---|---|---|{}\n", "---|".repeat(ALL_PASSES.len())));

        let mut improved = Vec::new();
        for (name, w) in &workloads {
            let naive = w.cycles(&PassConfig::none());
            let all = w.cycles(&PassConfig::all());
            improved.push((*name, naive, all));
            let mut cells = vec![
                name.to_string(),
                naive.to_string(),
                all.to_string(),
                format!("{:.2}x", naive as f64 / all as f64),
            ];
            let gain = naive as f64 / all as f64;
            let mut md_row = format!("| {name} | {naive} | {all} | {gain:.2}x |");
            for pass in ALL_PASSES {
                let without = w.cycles(&PassConfig::all().set(pass, false));
                let delta = without as i64 - all as i64;
                cells.push(delta.to_string());
                md_row.push_str(&format!(" {delta} |"));
            }
            t.row(&cells);
            md.push_str(&md_row);
            md.push('\n');
        }
        t.print();

        println!("\nmarkdown (paste into EXPERIMENTS.md §Pass ablation):\n{md}");

        // Transformation counts behind the deltas.
        for (name, spec) in [
            ("INT32 MUL", Spec::mul(DType::I32, MulImpl::Mulsi3)),
            ("INT8 MUL", Spec::mul(DType::I8, MulImpl::Mulsi3)),
        ] {
            let p = emit_microbench_with(spec, &PassConfig::none()).unwrap();
            let (_, stats) = optimize(&p, &PassConfig::all());
            println!(
                "{name}: {} call(s) inlined, {} static mul_steps elided, \
                 {} cond-jumps fused, {} unreachable instrs removed",
                stats.mul_calls_inlined,
                stats.mul_steps_elided,
                stats.cond_jumps_fused,
                stats.unreachable_removed
            );
        }

        println!("acceptance (paper directions):");
        for (name, naive, all) in &improved {
            let required = !name.contains("ADD"); // fusion-only row may tie on pointer latches
            let ok = if required { all < naive } else { all <= naive };
            println!(
                "  {} {name}: naive {naive} → all-on {all}",
                if ok { "PASS " } else { "DRIFT" }
            );
        }
        let dbuf_delta = {
            let w = &workloads.iter().find(|(n, _)| n.contains("INT8 GEMV")).unwrap().1;
            let without =
                w.cycles(&PassConfig::all().set(Pass::DmaDoubleBuffer, false)) as i64;
            let all = w.cycles(&PassConfig::all()) as i64;
            without - all
        };
        check("DMA double-buffering saves cycles at 8T (Δ ≥ 0)", dbuf_delta as f64, 0.0, 1e12);
    });
    footer("pass_ablation", wall);
}
