//! §Perf — simulator hot-path throughput (simulated instructions per
//! host second) plus per-workload *modeled cycles*. The interpreter
//! stands in for silicon, so its speed bounds every other bench;
//! EXPERIMENTS.md §Perf tracks the Minstr/s trajectory, while the
//! modeled-cycle column is deterministic and feeds the CI
//! perf-regression gate (`tools/check_perf_regression.py` against
//! `ci/BENCH_perf_baseline.json`, schema v2 via `bench_support/json`).
//!
//! The fleet-scale case runs the same 128-DPU (2-rank) GEMV launch
//! twice — pinned to 1 worker (the serial baseline) and on all
//! available cores — so the parallel fleet executor's speedup is
//! measured, not assumed; it then runs once per interpreter execution
//! tier (stepped / batched / superblock, `PIM_EXEC_TIER`) and prints
//! the tier comparison, asserting the tiers model identical cycles.
//! `PERF_SMOKE=1` shrinks every workload to CI size (host throughput
//! is then not comparable; modeled cycles remain exact for the smoke
//! sizes, which is what the gate diffs).

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::json::{json_perf_report, PerfMeta, WorkloadEntry};
use upmem_unleashed::bench_support::table::{f1, ratio, Table};
use upmem_unleashed::coordinator::GemvCoordinator;
use upmem_unleashed::dpu::{default_exec_tier, ExecTier};
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::arith::{run_microbench_with, DType, MulImpl, Spec, Unroll};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench_with, DotVariant};
use upmem_unleashed::kernels::gemv::{run_gemv_dpu_with_cfg, GemvShape, GemvVariant};
use upmem_unleashed::kernels::{histogram, reduce, scan, select, KernelScratch};
use upmem_unleashed::opt::PassConfig;
use upmem_unleashed::plane::{
    Linear, NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator,
};
use upmem_unleashed::telemetry::{
    chrome_trace_json, hotspot_markdown, profile_sink, trace_sink, TraceRecorder,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

/// Accumulates the table rows, the machine-readable entries and the
/// aggregate throughput. Every row is tagged with the execution tier
/// that produced it (the ambient `PIM_EXEC_TIER` default unless the
/// workload pinned one).
struct Perf {
    table: Table,
    entries: Vec<WorkloadEntry>,
    total_instrs: u64,
    total_secs: f64,
    ambient_tier: ExecTier,
}

fn perf_report() -> Perf {
    Perf {
        table: Table::new(
            "§Perf — simulator throughput (million simulated instrs / host second)",
            &["workload", "sim instrs", "host s", "Minstr/s", "modeled cycles", "tier"],
        ),
        entries: Vec::new(),
        total_instrs: 0,
        total_secs: 0.0,
        ambient_tier: default_exec_tier(),
    }
}

impl Perf {
    fn record(&mut self, name: &str, instrs: u64, secs: f64, cycles: Option<u64>) {
        let tier = self.ambient_tier;
        self.record_tier(name, instrs, secs, cycles, tier);
    }

    fn record_tier(
        &mut self,
        name: &str,
        instrs: u64,
        secs: f64,
        cycles: Option<u64>,
        tier: ExecTier,
    ) {
        let minstr = instrs as f64 / secs / 1e6;
        self.table.row(&[
            name.to_string(),
            instrs.to_string(),
            format!("{secs:.3}"),
            f1(minstr),
            cycles.map(|c| c.to_string()).unwrap_or_else(|| "—".into()),
            tier.name().to_string(),
        ]);
        self.entries.push(WorkloadEntry::new(name, minstr, cycles).with_tier(tier.name()));
        self.total_instrs += instrs;
        self.total_secs += secs;
    }
}

/// One fleet GEMV measurement: preload a `rows × cols` INT8 matrix over
/// a 128-DPU (2-rank) set, then time `reps` full-fleet launches.
/// `workers = None` keeps the system default (available parallelism /
/// `PIM_LAUNCH_WORKERS`); `tier = None` keeps the `PIM_EXEC_TIER`
/// default. Returns (total simulated instrs, host secs, per-launch max
/// modeled cycles).
fn fleet_gemv(
    workers: Option<usize>,
    tier: Option<ExecTier>,
    rows: u32,
    cols: u32,
    reps: usize,
) -> (u64, f64, u64) {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    if let Some(w) = workers {
        sys.set_launch_workers(w);
    }
    if let Some(t) = tier {
        sys.set_exec_tier(t);
    }
    let set = sys.alloc_ranks(2).expect("2 ranks");
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 16);
    let mut rng = Rng::new(4242);
    let m = rng.i8_vec((rows * cols) as usize);
    c.preload_matrix(rows, cols, &m).expect("preload");
    let mut instrs = 0u64;
    let mut max_cycles = 0u64;
    let (_, secs) = timed(|| {
        for _ in 0..reps {
            let fleet = c.sys.launch(&c.set, 16).expect("fleet launch");
            instrs += fleet.per_dpu.iter().map(|r| r.instrs).sum::<u64>();
            max_cycles = max_cycles.max(fleet.per_dpu.iter().map(|r| r.cycles).max().unwrap_or(0));
            c.sys.recycle_launch(fleet);
        }
    });
    (instrs, secs, max_cycles)
}

/// `PIM_TRACE` artifact: re-run the sharded fleet case with a span
/// recorder installed and write the Chrome trace-event JSON. The trace
/// is a pure function of the modeled clock — byte-identical across
/// runs and execution tiers, which is what CI diffs.
fn export_trace(path: &str, smoke: bool) {
    let (rows, cols) = if smoke { (256u32, 1024u32) } else { (1024, 2048) };
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).expect("2 shards x 1 rank");
    let map = ShardMap::new(sets, NumaBalanced.name()).expect("shard map");
    let mut c = ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 16);
    c.sys.install_trace(TraceRecorder::new());
    let mut rng = Rng::new(4242);
    let m = rng.i8_vec((rows * cols) as usize);
    c.preload_matrix(rows, cols, &m).expect("traced preload");
    let xs: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(cols as usize)).collect();
    let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
    c.gemv_pipelined(&views).expect("traced gemv");
    let tr = c.sys.take_trace().expect("recorder installed");
    match std::fs::write(path, chrome_trace_json(tr.events())) {
        Ok(()) => println!("wrote {path} ({} trace events)", tr.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// `PIM_PROFILE` artifact: run the fleet GEMV once with the per-PC
/// profiler enabled and write the markdown hotspot table. The profile
/// observes post-issue clocks, so it is identical across execution
/// tiers — CI `cmp`s the per-tier outputs byte-for-byte.
fn export_profile(path: &str, smoke: bool) {
    use upmem_unleashed::kernels::gemv::emit_gemv;
    let (rows, cols) = if smoke { (256u32, 1024u32) } else { (1024, 2048) };
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).expect("2 ranks");
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 16);
    let mut rng = Rng::new(4242);
    let m = rng.i8_vec((rows * cols) as usize);
    c.preload_matrix(rows, cols, &m).expect("profiled preload");
    c.sys.set_profile_enabled(&c.set, true);
    let fleet = c.sys.launch(&c.set, 16).expect("profiled launch");
    c.sys.recycle_launch(fleet);
    let profile = c.sys.collect_profile(&c.set);
    let program = emit_gemv(GemvVariant::I8Opt).expect("gemv program");
    let md = hotspot_markdown(
        "Fleet GEMV INT8 opt, 128 DPUs, 16 tasklets — per-PC issue profile",
        &profile,
        &program,
        12,
    );
    match std::fs::write(path, md) {
        Ok(()) => println!("wrote {path} ({} instrs profiled)", profile.total_instrs()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    if smoke {
        println!("[perf_simulator] PERF_SMOKE set: CI-sized workloads, numbers not comparable");
    }
    let (_, wall) = timed(|| {
        let mut p = perf_report();
        let mut scr = KernelScratch::default();
        let add_bytes: u32 = if smoke { 128 * 1024 } else { 1024 * 1024 };
        let mul_bytes: u32 = if smoke { 64 * 1024 } else { 512 * 1024 };
        let dot_elems: usize = if smoke { 32 * 1024 } else { 256 * 1024 };

        let (o, s) = timed(|| {
            run_microbench_with(
                &mut scr,
                Spec::add(DType::I8).with_unroll(Unroll::X64),
                16,
                add_bytes,
                42,
            )
            .unwrap()
            .launch
        });
        p.record("INT8 ADD x64, 16 tasklets", o.instrs, s, Some(o.cycles));

        let (o, s) = timed(|| {
            run_microbench_with(&mut scr, Spec::mul(DType::I8, MulImpl::Mulsi3), 16, mul_bytes, 42)
                .unwrap()
                .launch
        });
        p.record("INT8 MUL __mulsi3 (call-heavy), 16 tasklets", o.instrs, s, Some(o.cycles));

        let (o, s) = timed(|| {
            run_dot_microbench_with(&mut scr, DotVariant::Bsdp, 16, dot_elems, 42).unwrap().launch
        });
        p.record("BSDP dot (ALU-dense), 16 tasklets", o.instrs, s, Some(o.cycles));

        let (o, s) = timed(|| {
            run_microbench_with(&mut scr, Spec::add(DType::I8), 1, add_bytes, 42).unwrap().launch
        });
        p.record("single tasklet (scheduler idle-skip path)", o.instrs, s, Some(o.cycles));

        // PrIM workloads built on the kernel framework
        // (rust/src/framework/): deterministic modeled cycles for the
        // regression gate, Minstr/s for the throughput trajectory. The
        // runners verify every output against cpu_ref::prim, so each
        // row is also a correctness check at bench scale.
        let prim_elems: usize = if smoke { 16 * 1024 } else { 128 * 1024 };
        let mut prim_rng = Rng::new(2026);
        let prim_i32 = prim_rng.i32_vec(prim_elems);
        let prim_u8 = prim_rng.u8_vec(prim_elems * 4);
        let prim_cfg = PassConfig::all();
        let (o, s) = timed(|| {
            reduce::run_reduce_cfg_with(&mut scr, &prim_cfg, 16, &prim_i32).unwrap().launch
        });
        p.record("PrIM reduce (framework), 16 tasklets", o.instrs, s, Some(o.cycles));
        let (o, s) = timed(|| {
            histogram::run_histogram_cfg_with(&mut scr, &prim_cfg, 16, 256, &prim_u8)
                .unwrap()
                .launch
        });
        p.record("PrIM histogram 256 bins (framework), 16 tasklets", o.instrs, s, Some(o.cycles));
        let (o, s) = timed(|| {
            scan::run_scan_cfg_with(&mut scr, &prim_cfg, 16, &prim_i32).unwrap().launch
        });
        p.record("PrIM scan (framework), 16 tasklets", o.instrs, s, Some(o.cycles));
        let (o, s) = timed(|| {
            select::run_select_cfg_with(&mut scr, &prim_cfg, 16, &prim_i32).unwrap().launch
        });
        p.record("PrIM select (framework), 16 tasklets", o.instrs, s, Some(o.cycles));

        // Single-DPU GEMV per variant (+ the all-passes ablation point):
        // deterministic modeled cycles for the regression gate.
        let (rows, cols) = if smoke { (16u32, 1024u32) } else { (64, 2048) };
        let shape = GemvShape { rows, cols };
        // BSDP packs two INT4 elements per byte, so its row stride only
        // reaches the 1 KB chunk floor at twice the column count.
        let cols4 = cols * 2;
        let shape4 = GemvShape { rows, cols: cols4 };
        let mut rng = Rng::new(7);
        let m8 = rng.i8_vec((rows * cols) as usize);
        let x8 = rng.i8_vec(cols as usize);
        let m4 = rng.i4_vec((rows * cols4) as usize);
        let x4 = rng.i4_vec(cols4 as usize);
        let gemv_cases = [
            (
                "GEMV INT8 baseline, 1 DPU, 16 tasklets",
                GemvVariant::I8Baseline,
                GemvVariant::I8Baseline.default_passes(),
                16usize,
                m8.as_slice(),
                x8.as_slice(),
            ),
            (
                "GEMV INT8 opt, 1 DPU, 16 tasklets",
                GemvVariant::I8Opt,
                GemvVariant::I8Opt.default_passes(),
                16,
                m8.as_slice(),
                x8.as_slice(),
            ),
            (
                "GEMV INT8 opt all-passes + dbuf, 1 DPU, 8 tasklets",
                GemvVariant::I8Opt,
                PassConfig::all(),
                8,
                m8.as_slice(),
                x8.as_slice(),
            ),
            (
                "GEMV INT4 BSDP, 1 DPU, 16 tasklets",
                GemvVariant::I4Bsdp,
                GemvVariant::I4Bsdp.default_passes(),
                16,
                m4.as_slice(),
                x4.as_slice(),
            ),
        ];
        for (name, variant, cfg, tasklets, m, x) in gemv_cases {
            let sh = if variant == GemvVariant::I4Bsdp { shape4 } else { shape };
            let (r, s) = timed(|| {
                run_gemv_dpu_with_cfg(variant, &cfg, sh, tasklets, m, x).unwrap().1
            });
            p.record(name, r.instrs, s, Some(r.cycles));
        }

        // Fleet scale: serial baseline vs the parallel fleet executor.
        let (rows, cols, reps) = if smoke { (256u32, 1024u32, 1usize) } else { (1024, 2048, 3) };
        let default_workers =
            PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware).launch_workers();
        let (si, ss, sc) = fleet_gemv(Some(1), None, rows, cols, reps);
        p.record("fleet GEMV, 128 DPUs, 16 tasklets (1 worker)", si, ss, Some(sc));
        let (pi, ps, pc) = fleet_gemv(None, None, rows, cols, reps);
        // Stable name (no worker count): the JSON key must match the
        // committed gate baseline across runners with different core
        // counts — modeled cycles are worker-count-invariant anyway.
        println!("parallel fleet row uses {default_workers} worker threads");
        p.record("fleet GEMV, 128 DPUs, 16 tasklets (all cores)", pi, ps, Some(pc));
        let speedup = (pi as f64 / ps) / (si as f64 / ss);
        println!(
            "fleet parallel speedup: {} with {default_workers} worker threads",
            ratio(speedup)
        );
        p.entries.push(WorkloadEntry::new("fleet parallel speedup (x)", speedup, None));

        // Execution-tier comparison on the same fleet case (all cores):
        // stepped vs batched vs superblock — the two-tier engine's
        // acceptance row. Modeled cycles must agree bit-exactly across
        // tiers (enforced here and by the differential tests); host
        // Minstr/s is the payoff. The sweep pins each tier explicitly,
        // so it only runs under the default ambient tier — CI's
        // per-PIM_EXEC_TIER jobs would otherwise repeat the identical
        // sweep three times for no extra signal.
        if p.ambient_tier == ExecTier::Superblock {
            let mut tier_minstr = Vec::new();
            for tier in ExecTier::ALL {
                let (ti, tsec, tc) = fleet_gemv(None, Some(tier), rows, cols, reps);
                p.record_tier(
                    &format!("fleet GEMV, 128 DPUs, 16 tasklets [tier={}]", tier.name()),
                    ti,
                    tsec,
                    Some(tc),
                    tier,
                );
                tier_minstr.push((tier, ti as f64 / tsec / 1e6, tc));
            }
            let cycles0 = tier_minstr[0].2;
            assert!(
                tier_minstr.iter().all(|&(_, _, c)| c == cycles0),
                "tiers must model identical cycles: {tier_minstr:?}"
            );
            let stepped_m = tier_minstr[0].1;
            let batched_m = tier_minstr[1].1;
            let superblock_m = tier_minstr[2].1;
            println!(
                "fleet GEMV tier comparison: stepped {} / batched {} / superblock {} Minstr/s \
                 — superblock is {} vs stepped, {} vs batched",
                f1(stepped_m),
                f1(batched_m),
                f1(superblock_m),
                ratio(superblock_m / stepped_m),
                ratio(superblock_m / batched_m),
            );
            p.entries.push(WorkloadEntry::new(
                "superblock speedup vs stepped, fleet GEMV (x)",
                superblock_m / stepped_m,
                None,
            ));
            p.entries.push(WorkloadEntry::new(
                "superblock speedup vs batched, fleet GEMV (x)",
                superblock_m / batched_m,
                None,
            ));
            check(
                "superblock is the fastest tier (speedup vs best other tier ≥ 1x)",
                superblock_m / stepped_m.max(batched_m),
                1.0,
                1e9,
            );
        } else {
            println!(
                "tier comparison sweep skipped: ambient tier {} (runs under the \
                 superblock default)",
                p.ambient_tier.name()
            );
        }

        // Sharded data-plane fleet case (rust/src/plane/): the same
        // 128-DPU GEMV scale as the flat fleet rows, but routed through
        // a 2-shard NumaBalanced ShardMap — modeled cycles enter the
        // regression gate like any other workload, and the
        // Linear-vs-NumaBalanced modeled req/s ablation rides along as
        // deterministic `rate` rows.
        let (srows, scols) = if smoke { (256u32, 1024u32) } else { (1024, 2048) };
        let sharded_case = |policy: &dyn PlacementPolicy| {
            let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
            let sets = sys.alloc_shards(policy, 2, 1).expect("2 shards x 1 rank");
            let map = ShardMap::new(sets, policy.name()).expect("shard map");
            let mut c = ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 16);
            let mut rng = Rng::new(4242);
            let m = rng.i8_vec((srows * scols) as usize);
            c.preload_matrix(srows, scols, &m).expect("sharded preload");
            let xs: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(scols as usize)).collect();
            let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
            let (timing, secs) = timed(|| c.gemv_pipelined(&views).expect("sharded gemv").1);
            let reqps = views.len() as f64 / timing.total();
            (c.last_instrs(), secs, c.last_max_cycles(), reqps)
        };
        let (si, ss, sc, numa_reqps) = sharded_case(&NumaBalanced);
        p.record("sharded fleet GEMV, 2x64 DPUs, 16 tasklets [numa-balanced]", si, ss, Some(sc));
        let (_, _, lc, lin_reqps) = sharded_case(&Linear::default());
        assert_eq!(sc, lc, "placement must never change modeled compute cycles");
        println!(
            "sharded GEMV modeled serving rate: numa-balanced {:.1} req/s vs linear {:.1} req/s \
             ({} from placement alone)",
            numa_reqps,
            lin_reqps,
            ratio(numa_reqps / lin_reqps)
        );
        p.entries.push(
            WorkloadEntry::new("sharded GEMV modeled req/s [placement=numa-balanced]", 0.0, None)
                .with_rate(numa_reqps),
        );
        p.entries.push(
            WorkloadEntry::new("sharded GEMV modeled req/s [placement=linear]", 0.0, None)
                .with_rate(lin_reqps),
        );
        check(
            "NumaBalanced placement serves at least as fast as Linear (req/s ratio)",
            numa_reqps / lin_reqps,
            1.0,
            1e9,
        );

        p.table.print();
        let aggregate = p.total_instrs as f64 / p.total_secs / 1e6;
        println!("aggregate: {aggregate:.1} M simulated instructions / host second");
        p.entries.push(WorkloadEntry::new("aggregate", aggregate, None));

        let meta = PerfMeta {
            exec_tier: default_exec_tier().name().to_string(),
            smoke,
            launch_workers: default_workers,
        };
        println!("exec tier (ambient default): {}", meta.exec_tier);
        let json = json_perf_report(&p.entries, Some(&meta));
        match std::fs::write("BENCH_perf.json", &json) {
            Ok(()) => println!("wrote BENCH_perf.json ({} entries)", p.entries.len()),
            Err(e) => eprintln!("could not write BENCH_perf.json: {e}"),
        }

        // Observability artifacts, both off by default and zero-cost
        // when off (the span/profile hooks are one `None` branch).
        if let Some(path) = trace_sink("BENCH_trace.json") {
            export_trace(&path, smoke);
        }
        if let Some(path) = profile_sink("BENCH_hotspots.md") {
            export_profile(&path, smoke);
        }
    });
    footer("perf_simulator", wall);
}
