//! §Perf — simulator hot-path throughput (simulated instructions per
//! host second). The interpreter stands in for silicon, so its speed
//! bounds every other bench; EXPERIMENTS.md §Perf tracks this number
//! across optimization iterations, and `BENCH_perf.json` (written by
//! this bench, workload → Minstr/s) carries the trajectory PR-to-PR.
//!
//! The fleet-scale case runs the same 128-DPU (2-rank) GEMV launch
//! twice — pinned to 1 worker (the serial baseline) and on all
//! available cores — so the parallel fleet executor's speedup is
//! measured, not assumed. `PERF_SMOKE=1` shrinks every workload to CI
//! size (the point is exercising the bench + JSON writer, not stable
//! numbers).

mod common;

use common::{footer, timed};
use upmem_unleashed::bench_support::json::json_object;
use upmem_unleashed::bench_support::table::{f1, ratio, Table};
use upmem_unleashed::coordinator::GemvCoordinator;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::arith::{run_microbench_with, DType, MulImpl, Spec, Unroll};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench_with, DotVariant};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::kernels::KernelScratch;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

/// Accumulates the table rows, the machine-readable entries and the
/// aggregate throughput.
struct Perf {
    table: Table,
    entries: Vec<(String, f64)>,
    total_instrs: u64,
    total_secs: f64,
}

fn perf_report() -> Perf {
    Perf {
        table: Table::new(
            "§Perf — simulator throughput (million simulated instrs / host second)",
            &["workload", "sim instrs", "host s", "Minstr/s"],
        ),
        entries: Vec::new(),
        total_instrs: 0,
        total_secs: 0.0,
    }
}

impl Perf {
    fn record(&mut self, name: &str, instrs: u64, secs: f64) {
        let minstr = instrs as f64 / secs / 1e6;
        self.table.row(&[
            name.to_string(),
            instrs.to_string(),
            format!("{secs:.3}"),
            f1(minstr),
        ]);
        self.entries.push((name.to_string(), minstr));
        self.total_instrs += instrs;
        self.total_secs += secs;
    }
}

/// One fleet GEMV measurement: preload a `rows × cols` INT8 matrix over
/// a 128-DPU (2-rank) set, then time `reps` full-fleet launches.
/// `workers = None` keeps the system default (available parallelism /
/// `PIM_LAUNCH_WORKERS`). Returns (total simulated instrs, host secs).
fn fleet_gemv(workers: Option<usize>, rows: u32, cols: u32, reps: usize) -> (u64, f64) {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    if let Some(w) = workers {
        sys.set_launch_workers(w);
    }
    let set = sys.alloc_ranks(2).expect("2 ranks");
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 16);
    let mut rng = Rng::new(4242);
    let m = rng.i8_vec((rows * cols) as usize);
    c.preload_matrix(rows, cols, &m).expect("preload");
    let mut instrs = 0u64;
    let (_, secs) = timed(|| {
        for _ in 0..reps {
            let fleet = c.sys.launch(&c.set, 16).expect("fleet launch");
            instrs += fleet.per_dpu.iter().map(|r| r.instrs).sum::<u64>();
            c.sys.recycle_launch(fleet);
        }
    });
    (instrs, secs)
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    if smoke {
        println!("[perf_simulator] PERF_SMOKE set: CI-sized workloads, numbers not comparable");
    }
    let (_, wall) = timed(|| {
        let mut p = perf_report();
        let mut scr = KernelScratch::default();
        let add_bytes: u32 = if smoke { 128 * 1024 } else { 1024 * 1024 };
        let mul_bytes: u32 = if smoke { 64 * 1024 } else { 512 * 1024 };
        let dot_elems: usize = if smoke { 32 * 1024 } else { 256 * 1024 };

        let (i, s) = timed(|| {
            run_microbench_with(
                &mut scr,
                Spec::add(DType::I8).with_unroll(Unroll::X64),
                16,
                add_bytes,
                42,
            )
            .unwrap()
            .launch
            .instrs
        });
        p.record("INT8 ADD x64, 16 tasklets", i, s);

        let (i, s) = timed(|| {
            run_microbench_with(&mut scr, Spec::mul(DType::I8, MulImpl::Mulsi3), 16, mul_bytes, 42)
                .unwrap()
                .launch
                .instrs
        });
        p.record("INT8 MUL __mulsi3 (call-heavy), 16 tasklets", i, s);

        let (i, s) = timed(|| {
            run_dot_microbench_with(&mut scr, DotVariant::Bsdp, 16, dot_elems, 42)
                .unwrap()
                .launch
                .instrs
        });
        p.record("BSDP dot (ALU-dense), 16 tasklets", i, s);

        let (i, s) = timed(|| {
            run_microbench_with(&mut scr, Spec::add(DType::I8), 1, add_bytes, 42)
                .unwrap()
                .launch
                .instrs
        });
        p.record("single tasklet (scheduler idle-skip path)", i, s);

        // Fleet scale: serial baseline vs the parallel fleet executor.
        let (rows, cols, reps) = if smoke { (256u32, 1024u32, 1usize) } else { (1024, 2048, 3) };
        let default_workers =
            PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware).launch_workers();
        let (si, ss) = fleet_gemv(Some(1), rows, cols, reps);
        p.record("fleet GEMV, 128 DPUs, 16 tasklets (1 worker)", si, ss);
        let (pi, ps) = fleet_gemv(None, rows, cols, reps);
        p.record(
            &format!("fleet GEMV, 128 DPUs, 16 tasklets ({default_workers} workers)"),
            pi,
            ps,
        );
        let speedup = (pi as f64 / ps) / (si as f64 / ss);
        println!(
            "fleet parallel speedup: {} with {default_workers} worker threads",
            ratio(speedup)
        );
        p.entries.push(("fleet parallel speedup (x)".to_string(), speedup));

        p.table.print();
        let aggregate = p.total_instrs as f64 / p.total_secs / 1e6;
        println!("aggregate: {aggregate:.1} M simulated instructions / host second");
        p.entries.push(("aggregate".to_string(), aggregate));

        let json = json_object(&p.entries);
        match std::fs::write("BENCH_perf.json", &json) {
            Ok(()) => println!("wrote BENCH_perf.json ({} entries)", p.entries.len()),
            Err(e) => eprintln!("could not write BENCH_perf.json: {e}"),
        }
    });
    footer("perf_simulator", wall);
}
