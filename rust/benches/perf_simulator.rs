//! §Perf — simulator hot-path throughput (simulated instructions per
//! host second). The interpreter stands in for silicon, so its speed
//! bounds every other bench; EXPERIMENTS.md §Perf tracks this number
//! across optimization iterations.

mod common;

use common::{footer, timed};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec, Unroll};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench, DotVariant};

fn main() {
    let (_, wall) = timed(|| {
        let mut t = Table::new(
            "§Perf — simulator throughput (million simulated instrs / host second)",
            &["workload", "sim instrs", "host s", "Minstr/s"],
        );
        let mut total_i = 0u64;
        let mut total_s = 0.0;
        let cases: Vec<(&str, Box<dyn Fn() -> u64>)> = vec![
            (
                "INT8 ADD x64, 16 tasklets, 1 MB",
                Box::new(|| {
                    run_microbench(
                        Spec::add(DType::I8).with_unroll(Unroll::X64),
                        16,
                        1024 * 1024,
                        42,
                    )
                    .unwrap()
                    .launch
                    .instrs
                }),
            ),
            (
                "INT8 MUL __mulsi3 (call-heavy), 16 tasklets, 512 KB",
                Box::new(|| {
                    run_microbench(Spec::mul(DType::I8, MulImpl::Mulsi3), 16, 512 * 1024, 42)
                        .unwrap()
                        .launch
                        .instrs
                }),
            ),
            (
                "BSDP dot (ALU-dense), 16 tasklets, 256K elems",
                Box::new(|| {
                    run_dot_microbench(DotVariant::Bsdp, 16, 256 * 1024, 42)
                        .unwrap()
                        .launch
                        .instrs
                }),
            ),
            (
                "single tasklet (scheduler idle-skip path), 1 MB",
                Box::new(|| {
                    run_microbench(Spec::add(DType::I8), 1, 1024 * 1024, 42)
                        .unwrap()
                        .launch
                        .instrs
                }),
            ),
        ];
        for (name, f) in cases {
            let (instrs, s) = timed(&f);
            total_i += instrs;
            total_s += s;
            t.row(&[
                name.to_string(),
                instrs.to_string(),
                format!("{s:.3}"),
                f1(instrs as f64 / s / 1e6),
            ]);
        }
        t.print();
        println!(
            "aggregate: {:.1} M simulated instructions / host second",
            total_i as f64 / total_s / 1e6
        );
    });
    footer("perf_simulator", wall);
}
