//! Fig. 3 — baseline arithmetic performance of a single DPU vs tasklet
//! count (INT8/INT32 ADD/MUL, MOPS). Paper expectations: linear ramp to
//! a plateau at 11 tasklets; INT8 ADD ≈ 80, INT32 ADD ≈ 67 MOPS;
//! INT8 MUL ≈ 2.7× below ADD; INT32 MUL ≈ 6× below ADD.

mod common;

use common::{check, footer, timed, FIG_KB};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec};

fn main() {
    let (_, wall) = timed(|| {
        let mut t = Table::new(
            "Fig. 3 — baseline single-DPU arithmetic (MOPS)",
            &["tasklets", "INT8 ADD", "INT8 MUL", "INT32 ADD", "INT32 MUL"],
        );
        let mut at16 = [0.0f64; 4];
        for tk in [1usize, 2, 4, 8, 11, 12, 14, 16] {
            let m = |spec| run_microbench(spec, tk, FIG_KB * 1024, 42).unwrap().mops;
            let row = [
                m(Spec::add(DType::I8)),
                m(Spec::mul(DType::I8, MulImpl::Mulsi3)),
                m(Spec::add(DType::I32)),
                m(Spec::mul(DType::I32, MulImpl::Mulsi3)),
            ];
            if tk == 16 {
                at16 = row;
            }
            t.row(&[tk.to_string(), f1(row[0]), f1(row[1]), f1(row[2]), f1(row[3])]);
        }
        t.print();
        println!("paper targets at the plateau:");
        check("INT8 ADD MOPS", at16[0], 75.0, 85.0);
        check("INT32 ADD MOPS", at16[2], 62.0, 72.0);
        check("INT8 ADD/MUL gap", at16[0] / at16[1], 2.4, 3.1);
        check("INT32 ADD/MUL gap", at16[2] / at16[3], 5.2, 7.0);
        // Plateau check: 11 vs 16 tasklets within 2%.
        let m11 = run_microbench(Spec::add(DType::I8), 11, FIG_KB * 1024, 42).unwrap().mops;
        check("plateau m16/m11", at16[0] / m11, 0.98, 1.02);
    });
    footer("fig3", wall);
}
