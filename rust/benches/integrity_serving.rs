//! §Integrity — scrubbed serving under seeded corruption plans.
//!
//! Replays seeded open-loop traffic against two self-healing replicas
//! whose chaos plans inject silent corruption (MRAM bit flips and
//! in-flight transfer corruptions into the resident matrix blocks),
//! while the sim schedules periodic in-PIM scrub cycles on the modeled
//! clock. Detection happens by checksum diff against the host golden
//! table, repair is a delta re-push of exactly the corrupted block —
//! so the measured rows quantify the integrity plane's serving cost:
//!
//! * gated: modeled req/s with scrubbing on, and the detection rate
//!   (corruptions caught / corruptions injected);
//! * informational: scrub overhead (fraction of the run's modeled time
//!   spent scrubbing + repairing) and mean time-to-repair.
//!
//! Everything is threadless and modeled, so every row is a pure
//! function of (seed, tier) and CI compares the gated rows exactly
//! across execution tiers. `PERF_SMOKE=1` shrinks the request stream.

mod common;

use common::{check, footer, timed};
use upmem_unleashed::bench_support::json::{json_perf_report, PerfMeta, WorkloadEntry};
use upmem_unleashed::bench_support::table::{f1, Table};
use upmem_unleashed::chaos::{ChaosConfig, ChaosInjector, ChaosPlan, SelfHealingCoordinator};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::dpu::default_exec_tier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::traffic::{
    AdmissionConfig, AdmissionPolicy, ArrivalProcess, DeadlineBatcher, OpenLoopSim, SimConfig,
    TrafficConfig, TrafficPlan, WorkloadMix,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

const ROWS: u32 = 128;
const COLS: u32 = 512;
const BATCH: usize = 4;
const REPLICAS: usize = 2;
/// One row per DPU at this shape — every per-DPU block is 512 B.
const BLOCK_BYTES: u64 = 512;
/// Committed seeds — CI replays exactly these.
const SEEDS: [u64; 2] = [11, 23];

fn preloaded(m: &[i8]) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).expect("2 shards x 1 rank");
    let map = ShardMap::new(sets, NumaBalanced.name()).expect("shard map");
    let mut c = ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8);
    c.preload_matrix(ROWS, COLS, m).expect("preload");
    c
}

/// Modeled seconds per full pipelined batch — the saturation unit.
fn batch_seconds(m: &[i8]) -> f64 {
    let mut c = preloaded(m);
    let xs: Vec<Vec<i8>> = (0..BATCH).map(|i| vec![i as i8 + 1; COLS as usize]).collect();
    let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
    let t0 = c.sys.sync_all();
    c.gemv_pipelined(&views).expect("calibration batch");
    c.sys.sync_all() - t0
}

fn main() {
    let smoke = std::env::var("PERF_SMOKE").is_ok();
    if smoke {
        println!("[integrity_serving] PERF_SMOKE set: CI-sized request stream");
    }
    let requests: usize = if smoke { 12 } else { 36 };
    let (_, wall) = timed(|| {
        let m = Rng::new(4242).i8_vec((ROWS * COLS) as usize);
        let dt = batch_seconds(&m);
        let sat_pool = REPLICAS as f64 * BATCH as f64 / dt;
        println!(
            "calibration: {dt:.6} modeled s per {BATCH}-batch → pool saturation {sat_pool:.1} req/s"
        );
        let mut entries: Vec<WorkloadEntry> = Vec::new();
        let mut table = Table::new(
            "§Integrity — scrubbed serving under seeded corruption",
            &[
                "scenario",
                "req/s (modeled)",
                "injected",
                "detected",
                "repaired",
                "detection rate",
                "scrub overhead",
                "mttr (modeled s)",
            ],
        );

        for seed in SEEDS {
            let plan = TrafficPlan::generate(
                seed,
                &TrafficConfig {
                    process: ArrivalProcess::Poisson { rate_rps: 0.8 * sat_pool },
                    requests,
                    deadline_s: Some(50.0 * dt),
                    mix: WorkloadMix::single(ROWS, COLS, GemvVariant::I8Opt),
                },
            );
            let replicas: Vec<SelfHealingCoordinator> = (0..REPLICAS as u64)
                .map(|r| {
                    let mut c = preloaded(&m);
                    let victims: Vec<usize> = (0..2)
                        .flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec())
                        .collect();
                    let ccfg = ChaosConfig {
                        ops: 6,
                        dpu_deaths: 0,
                        transient_launches: 0,
                        transient_transfers: 0,
                        stragglers: 0,
                        mram_bit_flips: 2,
                        transfer_corruptions: 1,
                        corrupt_mram_len: BLOCK_BYTES as u32,
                        ..ChaosConfig::default()
                    };
                    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(
                        seed + 100 * (r + 1),
                        &ccfg,
                        &victims,
                    )));
                    SelfHealingCoordinator::new(c)
                })
                .collect();
            let mut sim = OpenLoopSim::new(
                SimConfig {
                    batcher: DeadlineBatcher::new(BATCH, 0.5 * dt),
                    admission: AdmissionConfig {
                        policy: AdmissionPolicy::RejectNew,
                        queue_cap: 2 * BATCH,
                    },
                    policy: Policy::SloAware,
                },
                vec![replicas],
            );
            sim.set_scrub_every(0.5 * dt);
            let rep = sim.run(&plan, &[]);
            let im = &rep.integrity;

            check(
                &format!("seed {seed}: every request served or typed-shed"),
                (rep.served.len() + rep.rejections.len() + rep.failed.len()) as f64,
                requests as f64,
                requests as f64,
            );
            check(
                &format!("seed {seed}: the committed plans inject corruption"),
                if im.injected > 0 { 1.0 } else { 0.0 },
                1.0,
                1.0,
            );
            check(
                &format!("seed {seed}: repairs are delta-only (one block each)"),
                im.repaired_bytes as f64,
                BLOCK_BYTES as f64 * im.repaired as f64,
                BLOCK_BYTES as f64 * im.repaired as f64,
            );
            // Two draws landing in one block within a scrub interval
            // collapse into a single detection, so the rate may dip
            // below 1.0 — but never below half on the committed seeds.
            let detection = if im.injected == 0 {
                0.0
            } else {
                im.detected as f64 / im.injected as f64
            };
            check(&format!("seed {seed}: detection rate"), detection, 0.5, 1.0);

            // Fraction of the run's modeled span spent in integrity
            // work (scrub passes + repairs), the plane's serving cost.
            let span = if rep.throughput_rps() > 0.0 {
                rep.served.len() as f64 / rep.throughput_rps()
            } else {
                0.0
            };
            let overhead = if span > 0.0 { (im.scrub_s + im.repair_s) / span } else { 0.0 };

            table.row(&[
                format!("seed={seed} 0.8x scrubbed"),
                f1(rep.throughput_rps()),
                im.injected.to_string(),
                im.detected.to_string(),
                im.repaired.to_string(),
                format!("{detection:.3}"),
                format!("{overhead:.3}"),
                format!("{:.6}", im.mean_time_to_repair_s()),
            ]);

            let tag = format!("[seed={seed}]");
            entries.push(
                WorkloadEntry::new(format!("integrity serving modeled req/s {tag}"), 0.0, None)
                    .with_rate(rep.throughput_rps()),
            );
            entries.push(
                WorkloadEntry::new(format!("integrity detection rate (fraction) {tag}"), 0.0, None)
                    .with_rate(detection),
            );
            // Informational (ungated): overhead and repair latency are
            // costs — lower is better, the opposite gating direction.
            entries.push(WorkloadEntry::new(
                format!("integrity scrub overhead (fraction, informational) {tag}"),
                overhead,
                None,
            ));
            entries.push(WorkloadEntry::new(
                format!("integrity mean time-to-repair (modeled s, informational) {tag}"),
                im.mean_time_to_repair_s(),
                None,
            ));
        }

        table.print();

        let meta = PerfMeta {
            exec_tier: default_exec_tier().name().to_string(),
            smoke,
            launch_workers: PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware)
                .launch_workers(),
        };
        let json = json_perf_report(&entries, Some(&meta));
        match std::fs::write("BENCH_serving_integrity.json", &json) {
            Ok(()) => println!("wrote BENCH_serving_integrity.json ({} entries)", entries.len()),
            Err(e) => eprintln!("could not write BENCH_serving_integrity.json: {e}"),
        }
    });
    footer("integrity_serving", wall);
}
