//! Satellite: the BSDP dot-product microbench scaffold is now generated
//! by `framework::stride`. This test pins the port bit-identically: a
//! FROZEN verbatim copy of the original hand-emitted scaffold (as it
//! existed before the port) is compared instruction-for-instruction
//! against the framework-generated stream, naive and after each
//! variant's canonical pass pipeline.
//!
//! If the framework layer ever drifts — one reordered move, a different
//! register, an extra shift — these assertions fail, which is the whole
//! point: the layer must reproduce hand-tuned code exactly, not just
//! compute the same values.

use upmem_unleashed::dpu::builder::ProgramBuilder;
use upmem_unleashed::dpu::isa::{CmpCond, Program, Reg, Src};
use upmem_unleashed::kernels::bsdp::{
    emit_dot_chunk, emit_dot_microbench_naive, DotVariant, R_ACC, R_APTR, R_BPTR,
};
use upmem_unleashed::kernels::mulsi3::emit_mulsi3;
use upmem_unleashed::kernels::{AUX_BASE, BUF_BASE, CYCLES_BASE, MRAM_A, MRAM_B};
use upmem_unleashed::opt::optimize;
use upmem_unleashed::Result;

// ---- frozen copy of the pre-port hand emitter --------------------------
// Do not "fix" or modernize this: it is the reference artifact.

const R_T0: Reg = Reg(15);
const R_T1: Reg = Reg(16);
const R_CYC: Reg = Reg(17);
const R_END: Reg = Reg(19);
const R_BUFA: Reg = Reg(20);
const R_MPTR: Reg = Reg(21);
const R_STRIDE: Reg = Reg(22);
const R_BUFB: Reg = Reg(13);
const R_MOFF_B: Reg = Reg(14);
const CHUNK: u32 = 1024;

fn frozen_hand_emitter(variant: DotVariant) -> Result<Program> {
    let mut pb = ProgramBuilder::new();
    upmem_unleashed::kernels::def_convention_symbols(&mut pb);
    let main = pb.new_label("main");
    pb.jump(main);
    let mulsi3 = if variant == DotVariant::NativeMulsi3 {
        Some(emit_mulsi3(&mut pb))
    } else {
        None
    };
    pb.bind(main);

    pb.move_(R_BUFA, Src::Id8);
    pb.lsl(R_BUFA, R_BUFA, 8);
    pb.add(R_BUFA, R_BUFA, BUF_BASE as i32);
    pb.add(R_BUFB, R_BUFA, CHUNK as i32);
    pb.move_(R_MPTR, Src::Id8);
    pb.lsl(R_MPTR, R_MPTR, 7);
    pb.add(R_MPTR, R_MPTR, MRAM_A as i32);
    pb.move_(R_MOFF_B, (MRAM_B - MRAM_A) as i32);
    pb.move_(Reg(3), 0);
    pb.lw(R_END, Reg(3), 0);
    pb.add(R_END, R_END, MRAM_A as i32);
    pb.lw(R_STRIDE, Reg(3), 8);
    pb.move_(R_CYC, 0);
    pb.move_(R_ACC, Src::Zero);

    let done = pb.new_label("done");
    pb.jcmp(CmpCond::Geu, R_MPTR, Src::Reg(R_END), done);
    let blocks = pb.here("blocks");
    pb.ldma(R_BUFA, R_MPTR, CHUNK);
    pb.add(Reg(3), R_MPTR, Src::Reg(R_MOFF_B));
    pb.ldma(R_BUFB, Reg(3), CHUNK);
    pb.barrier();
    pb.time(R_T0);
    pb.move_(R_APTR, R_BUFA);
    pb.move_(R_BPTR, R_BUFB);
    let elems = match variant {
        DotVariant::Bsdp => CHUNK * 2,
        _ => CHUNK,
    };
    emit_dot_chunk(&mut pb, variant, elems, mulsi3);
    pb.time(R_T1);
    pb.sub(R_T1, R_T1, R_T0);
    pb.add(R_CYC, R_CYC, R_T1);
    pb.barrier();
    pb.add(R_MPTR, R_MPTR, Src::Reg(R_STRIDE));
    pb.jcmp(CmpCond::Ltu, R_MPTR, Src::Reg(R_END), blocks);
    pb.bind(done);
    pb.move_(Reg(3), Src::Id4);
    pb.add(Reg(3), Reg(3), CYCLES_BASE as i32);
    pb.sw(Reg(3), 0, R_CYC);
    pb.move_(Reg(3), Src::Id4);
    pb.add(Reg(3), Reg(3), AUX_BASE as i32);
    pb.sw(Reg(3), 0, R_ACC);
    pb.stop();
    pb.build()
}

// ---- pins --------------------------------------------------------------

const ALL_VARIANTS: [DotVariant; 4] = [
    DotVariant::NativeBaseline,
    DotVariant::NativeMulsi3,
    DotVariant::NativeOptimized,
    DotVariant::Bsdp,
];

#[test]
fn framework_reproduces_hand_emitter_naive() {
    for v in ALL_VARIANTS {
        let frozen = frozen_hand_emitter(v).unwrap();
        let ported = emit_dot_microbench_naive(v).unwrap();
        assert_eq!(
            ported.instrs,
            frozen.instrs,
            "{}: framework naive stream drifted from the hand emitter",
            v.name()
        );
    }
}

#[test]
fn framework_reproduces_hand_emitter_optimized() {
    for v in ALL_VARIANTS {
        let cfg = v.default_passes();
        let frozen = optimize(&frozen_hand_emitter(v).unwrap(), &cfg).0;
        let ported = optimize(&emit_dot_microbench_naive(v).unwrap(), &cfg).0;
        assert_eq!(
            ported.instrs,
            frozen.instrs,
            "{}: framework optimized stream drifted from the hand emitter",
            v.name()
        );
    }
}

#[test]
fn ported_microbench_still_verifies_against_host_reference() {
    // End-to-end sanity on top of the stream pins: the ported kernel
    // still computes correct dot products (the runner self-verifies).
    for v in ALL_VARIANTS {
        let out = upmem_unleashed::kernels::bsdp::run_dot_microbench(v, 4, 8192, 7).unwrap();
        assert_eq!(out.elems, 8192, "{}", v.name());
    }
}
