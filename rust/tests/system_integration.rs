//! System-level integration over the pure-rust stack (no artifacts
//! needed): allocation policies × transfer engine × DPU fleet ×
//! coordinator × serving layer, plus fault injection.

use std::time::Duration;

use upmem_unleashed::config::{ConfigDoc, GemvJob, RunConfig};
use upmem_unleashed::coordinator::{Batcher, GemvCoordinator, GemvServer};
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::proptest::{forall, Config};
use upmem_unleashed::util::rng::Rng;

#[test]
fn gemv_correct_under_both_allocation_policies() {
    for policy in [AllocPolicy::NumaAware, AllocPolicy::BaselineSdk { boot_seed: 5 }] {
        let mut sys = PimSystem::new(SystemTopology::pristine(), policy);
        let set = sys.alloc_ranks(2).unwrap();
        let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
        let mut rng = Rng::new(81);
        let (rows, cols) = (256u32, 1024u32);
        let m = rng.i8_vec((rows * cols) as usize);
        let x = rng.i8_vec(cols as usize);
        c.preload_matrix(rows, cols, &m).unwrap();
        let (y, t) = c.gemv(&x).unwrap();
        assert_eq!(y, gemv_ref(GemvShape { rows, cols }, &m, &x));
        // The policy changes timing, never results.
        assert!(t.total() > 0.0);
    }
}

#[test]
fn numa_policy_is_faster_end_to_end() {
    let run = |policy| {
        let mut sys = PimSystem::new(SystemTopology::pristine(), policy);
        let set = sys.alloc_ranks(4).unwrap();
        let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
        let mut rng = Rng::new(82);
        let (rows, cols) = (512u32, 1024u32);
        let m = rng.i8_vec((rows * cols) as usize);
        let x = rng.i8_vec(cols as usize);
        let (_, t) = c.gemv_with_matrix(rows, cols, &m, &x).unwrap();
        t
    };
    let numa = run(AllocPolicy::NumaAware);
    let base = run(AllocPolicy::BaselineSdk { boot_seed: 1 });
    // Same compute, slower transfers under the baseline allocator.
    assert!((numa.compute_s - base.compute_s).abs() < 1e-9);
    assert!(base.matrix_s > numa.matrix_s, "numa={} base={}", numa.matrix_s, base.matrix_s);
}

#[test]
fn faulty_dpus_are_transparent_to_results() {
    // The paper's machine has 9 disabled DPUs; work must still be
    // partitioned only over usable units with identical results.
    let mut healthy = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let mut faulty = PimSystem::new(SystemTopology::paper_server(), AllocPolicy::NumaAware);
    let sh = healthy.alloc_ranks(40).unwrap();
    let sf = faulty.alloc_ranks(40).unwrap();
    assert_eq!(sh.nr_dpus(), 2560);
    assert_eq!(sf.nr_dpus(), 2551);

    // Run a small GEMV over a 2-rank subset of the faulty machine that
    // actually contains a disabled DPU.
    let topo = SystemTopology::paper_server();
    let has_fault = (64..192).any(|d| topo.is_faulty(d));
    assert!(has_fault, "ranks 1-2 should contain an injected fault");
    let mut sys = PimSystem::new(topo, AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
    let mut rng = Rng::new(83);
    let (rows, cols) = (300u32, 1024u32);
    let m = rng.i8_vec((rows * cols) as usize);
    let x = rng.i8_vec(cols as usize);
    c.preload_matrix(rows, cols, &m).unwrap();
    let (y, _) = c.gemv(&x).unwrap();
    assert_eq!(y, gemv_ref(GemvShape { rows, cols }, &m, &x));
}

#[test]
fn serving_stack_under_concurrent_clients() {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
    let mut rng = Rng::new(84);
    let (rows, cols) = (128u32, 1024u32);
    let m = rng.i8_vec((rows * cols) as usize);
    c.preload_matrix(rows, cols, &m).unwrap();
    let (server, client) = GemvServer::start(c, Batcher::new(4, Duration::from_micros(300)));

    // Three client threads, each submitting its own vectors.
    let handles: Vec<_> = (0..3)
        .map(|t| {
            let cl = client.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let mut ok = 0;
                for _ in 0..4 {
                    let x = rng.i8_vec(1024);
                    if cl.call(x).map(|r| r.y.is_ok()).unwrap_or(false) {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let (_, metrics) = server.shutdown();
    assert_eq!(total, 12);
    assert_eq!(metrics.requests, 12);
    assert_eq!(metrics.errors, 0);
    assert!(metrics.batches <= 12);
}

#[test]
fn config_driven_pipeline() {
    let doc = ConfigDoc::parse(
        "[system]\nranks = 2\ntasklets = 8\npolicy = \"numa\"\nseed = 9\n\
         [gemv]\nrows = 128\ncols = 2048\nvariant = \"i4-bsdp\"\n",
    )
    .unwrap();
    let run = RunConfig::from_doc(&doc).unwrap();
    let job = GemvJob::from_doc(&doc).unwrap();
    let mut sys = run.build_system();
    let set = sys.alloc_ranks(run.ranks).unwrap();
    let mut c = GemvCoordinator::new(sys, set, job.variant, run.tasklets);
    let mut rng = Rng::new(run.seed);
    let m = rng.i4_vec((job.rows * job.cols) as usize);
    let x = rng.i4_vec(job.cols as usize);
    c.preload_matrix(job.rows, job.cols, &m).unwrap();
    let (y, _) = c.gemv(&x).unwrap();
    assert_eq!(y, gemv_ref(GemvShape { rows: job.rows, cols: job.cols }, &m, &x));
}

#[test]
fn fleet_gemv_property_random_shapes() {
    // Property: for random (rows, cols, tasklets, variant), the fleet
    // result equals the host reference.
    forall(
        Config::cases(8),
        |rng| {
            let rows = rng.range_u64(1, 300) as u32;
            let cols = *rng.choose(&[1024u32, 2048]);
            let tasklets = rng.range_u64(1, 16) as usize;
            let bsdp = rng.f64() < 0.5;
            let seed = rng.next_u64();
            (rows, cols, tasklets, bsdp, seed)
        },
        |&(rows, cols, tasklets, bsdp, seed)| {
            let variant = if bsdp { GemvVariant::I4Bsdp } else { GemvVariant::I8Opt };
            if bsdp && cols == 1024 {
                return true; // BSDP needs ≥2048 columns (1 KB chunks)
            }
            let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
            let set = sys.alloc_ranks(2).unwrap();
            let mut c = GemvCoordinator::new(sys, set, variant, tasklets);
            let mut rng = Rng::new(seed);
            let (m, x) = if bsdp {
                (rng.i4_vec((rows * cols) as usize), rng.i4_vec(cols as usize))
            } else {
                (rng.i8_vec((rows * cols) as usize), rng.i8_vec(cols as usize))
            };
            c.preload_matrix(rows, cols, &m).unwrap();
            let (y, _) = c.gemv(&x).unwrap();
            y == gemv_ref(GemvShape { rows, cols }, &m, &x)
        },
        "fleet GEMV == host reference for random shapes",
    );
}
