//! Property-based tests over the kernel and substrate invariants
//! (mini-harness in `util::proptest`; the offline cache has no
//! proptest crate).

use upmem_unleashed::dpu::builder::ProgramBuilder;
use upmem_unleashed::dpu::isa::CmpCond;
use upmem_unleashed::dpu::{assemble, Dpu, ExecTier, Program, Reg, Src};
use upmem_unleashed::kernels::arith::{
    emit_microbench, run_microbench, DType, MulImpl, Spec, Unroll,
};
use upmem_unleashed::kernels::encode;
use upmem_unleashed::kernels::mulsi3::{emit_mulsi3, ARG_A, ARG_B, LINK, RESULT};
use upmem_unleashed::opt::{PassConfig, ALL_PASSES};
use upmem_unleashed::transfer::model::BufferPlacement;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::transfer::{Direction, TransferModel};
use upmem_unleashed::util::proptest::{forall, Config};
use upmem_unleashed::util::rng::Rng;

/// Every microbenchmark variant produces identical MRAM contents no
/// matter the unroll factor or tasklet count — unrolling is a pure
/// performance transformation.
#[test]
fn unrolling_never_changes_results() {
    forall(
        Config::cases(12),
        |rng| {
            let dtype = if rng.f64() < 0.5 { DType::I8 } else { DType::I32 };
            let mimpl = *rng.choose(&[MulImpl::Mulsi3, MulImpl::Native, MulImpl::Dim]);
            let unroll = *rng.choose(&[Unroll::X64, Unroll::X128]);
            let tasklets = rng.range_u64(1, 16) as usize;
            let seed = rng.next_u64();
            (dtype, mimpl, unroll, tasklets, seed)
        },
        |&(dtype, mimpl, unroll, tasklets, seed)| {
            // Skip invalid combos (native/dim constraints per dtype).
            let spec = match (dtype, mimpl) {
                (DType::I8, MulImpl::Dim) => return true,
                (DType::I32, MulImpl::Native) => return true,
                _ => Spec { dtype, op: upmem_unleashed::kernels::arith::Op::Mul, mimpl, unroll },
            };
            // run_microbench verifies outputs internally (Err on
            // mismatch), and the unrolled variant must agree too.
            run_microbench(spec.with_unroll(Unroll::No), tasklets, 8 * 1024, seed).is_ok()
                && run_microbench(spec, tasklets, 8 * 1024, seed).is_ok()
        },
        "unroll factor never changes kernel results",
    );
}

/// Cycle counts are deterministic: same spec + seed ⇒ identical cycles.
#[test]
fn simulation_is_deterministic() {
    let spec = Spec::mul(DType::I8, MulImpl::NativeX8);
    let a = run_microbench(spec, 16, 16 * 1024, 9).unwrap();
    let b = run_microbench(spec, 16, 16 * 1024, 9).unwrap();
    assert_eq!(a.launch.cycles, b.launch.cycles);
    assert_eq!(a.launch.instrs, b.launch.instrs);
    assert_eq!(a.tasklet_cycles, b.tasklet_cycles);
}

/// MOPS never decreases when tasklets are added (monotone ramp).
#[test]
fn tasklet_scaling_is_monotone() {
    let spec = Spec::add(DType::I8);
    let bytes = 176 * 1024;
    let mut last = 0.0;
    for t in 1..=16 {
        let m = run_microbench(spec, t, bytes, 4).unwrap().mops;
        // Allow a ≤2.5 % dip from uneven block assignment when the
        // tasklet count does not divide the block count (the paper's
        // 1M-element buffer smooths this the same way).
        assert!(m >= 0.975 * last, "t={t}: {m} < {last}");
        last = last.max(m);
    }
}

/// Disassembly round-trips through the assembler for every emitted
/// microbenchmark program.
#[test]
fn disasm_roundtrip_for_all_kernels() {
    for spec in [
        Spec::add(DType::I8),
        Spec::add(DType::I32).with_unroll(Unroll::X64),
        Spec::mul(DType::I8, MulImpl::Mulsi3),
        Spec::mul(DType::I8, MulImpl::NativeX8),
        Spec::mul(DType::I32, MulImpl::Dim),
    ] {
        let p1 = emit_microbench(spec).unwrap();
        let p2 = assemble(&p1.disasm()).unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
        assert_eq!(p1.instrs, p2.instrs, "{}", spec.name());
    }
}

/// Bit-plane encode/decode is a bijection on valid INT4 vectors, and
/// the encoded form is exactly half the INT8 storage.
#[test]
fn bitplane_encoding_properties() {
    forall(
        Config::cases(100),
        |rng| {
            let n = rng.range_u64(1, 64) as usize * 32;
            rng.i4_vec(n)
        },
        |vals| {
            let planes = encode::bitplane_encode_i4(vals);
            planes.len() * 4 == vals.len() / 2 && encode::bitplane_decode_i4(&planes) == *vals
        },
        "bitplane encode/decode bijection + 2x density",
    );
}

/// BSDP evaluated on planes equals the direct signed dot product for
/// random vectors (host-side Algorithm 2 oracle).
#[test]
fn bsdp_plane_evaluation_matches_dot() {
    forall(
        Config::cases(60),
        |rng| {
            let n = rng.range_u64(1, 16) as usize * 32;
            (rng.i4_vec(n), rng.i4_vec(n))
        },
        |(a, b)| {
            let got = encode::bsdp_eval_i4(
                &encode::bitplane_encode_i4(a),
                &encode::bitplane_encode_i4(b),
            );
            got == encode::dot_i4_ref(a, b)
        },
        "bit-serial == direct dot product",
    );
}

/// Transfer model: adding ranks to a balanced allocation never reduces
/// throughput, and PerSocket placement is never slower than pinning to
/// one node.
#[test]
fn transfer_model_monotonicity() {
    let topo = SystemTopology::pristine();
    let model = TransferModel::default();
    let balanced = |n: usize| -> Vec<usize> {
        // one rank per channel, alternating sockets
        let mut out = Vec::new();
        'outer: for round in 0..4 {
            for c in 0..5 {
                for s in 0..2 {
                    if out.len() >= n {
                        break 'outer;
                    }
                    out.push(topo.ranks_of_channel(s, c)[round]);
                }
            }
        }
        out
    };
    let bytes = 1u64 << 30;
    let mut last_gbps = 0.0;
    for n in [1usize, 2, 4, 8, 16, 32, 40] {
        let ranks = balanced(n);
        for dir in [Direction::HostToPim, Direction::PimToHost] {
            let t_per =
                model.parallel_seconds(&topo, &ranks, bytes, dir, BufferPlacement::PerSocket);
            let t_pin = model.parallel_seconds(&topo, &ranks, bytes, dir,
                BufferPlacement::Node(0));
            assert!(t_per <= t_pin + 1e-12, "n={n} {dir:?}");
        }
        let gbps = bytes as f64
            / model.parallel_seconds(
                &topo,
                &ranks,
                bytes,
                Direction::HostToPim,
                BufferPlacement::PerSocket,
            );
        assert!(gbps >= last_gbps * (1.0 - 1e-9), "n={n}: {gbps} < {last_gbps}");
        last_gbps = gbps;
    }
}

/// Fault injection: a DPU program that faults on one DPU surfaces the
/// *global* DPU id through the host layer.
#[test]
fn fleet_fault_reports_global_dpu_id() {
    use upmem_unleashed::host::{AllocPolicy, PimSystem};
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    // Fault only where WRAM[0] == magic, planted on one DPU.
    let prog = assemble(
        "move r1, 0\n\
         lw r0, r1, 0\n\
         jneq r0, 77, @ok\n\
         fault\n\
         ok:\n\
         stop\n",
    )
    .unwrap();
    sys.load_program(&set, &prog).unwrap();
    // Hand-assembled program: declare the magic word as an ad-hoc
    // typed symbol instead of a raw WRAM offset.
    let magic = upmem_unleashed::dpu::Symbol::<u32>::wram("magic", 0, 1);
    sys.write_symbol(&set, &magic, |i| if i == 100 { 77 } else { 0 }).unwrap();
    let err = sys.launch(&set, 4).unwrap_err();
    match err {
        upmem_unleashed::Error::Fault { dpu, .. } => {
            assert_eq!(dpu, set.dpus[100], "fault must carry the global DPU id");
        }
        other => panic!("unexpected error: {other}"),
    }
}

/// The `__mulsi3` reconstruction agrees with wrapping multiplication on
/// a large randomized sweep run through the *microbenchmark* (end to
/// end through MRAM staging, not just the routine harness).
#[test]
fn mulsi3_sweep_through_microbench() {
    forall(
        Config::cases(6),
        |rng| rng.next_u64(),
        |&seed| {
            run_microbench(Spec::mul(DType::I32, MulImpl::Mulsi3), 8, 8 * 1024, seed).is_ok()
        },
        "__mulsi3 microbenchmark verifies on random data",
    );
}

/// Random-program smoke fuzz: assembling the disassembly of random
/// (valid) straight-line ALU programs round-trips and executes without
/// faulting.
#[test]
fn straightline_program_fuzz() {
    forall(
        Config::cases(40),
        |rng| {
            let n = rng.range_u64(1, 60);
            let mut src = String::new();
            for _ in 0..n {
                let rd = rng.range_u64(0, 7);
                let ra = rng.range_u64(0, 7);
                let op = *rng.choose(&["add", "sub", "and", "or", "xor", "lsl", "lsr", "asr"]);
                let imm = rng.range_i64(-128, 127);
                src.push_str(&format!("{op} r{rd}, r{ra}, {imm}\n"));
            }
            src.push_str("stop\n");
            src
        },
        |src| {
            let Ok(p1) = assemble(src) else { return false };
            let Ok(p2) = assemble(&p1.disasm()) else { return false };
            if p1.instrs != p2.instrs {
                return false;
            }
            let mut dpu = Dpu::new();
            dpu.load_program(&p1).unwrap();
            dpu.launch(4).is_ok()
        },
        "random straight-line programs round-trip and run",
    );
}

/// The full `MulImpl` × `Unroll` matrix over both dtypes: every valid
/// combination builds, runs and verifies (the runner checks every
/// element against the host reference); `Unroll::Auto` may instead
/// overflow IRAM — the paper's `#pragma unroll` linker error — which is
/// the only acceptable failure.
#[test]
fn full_mulimpl_unroll_matrix() {
    let specs: Vec<Spec> = vec![
        Spec::add(DType::I8),
        Spec::add(DType::I32),
        Spec::mul(DType::I8, MulImpl::Mulsi3),
        Spec::mul(DType::I8, MulImpl::Native),
        Spec::mul(DType::I8, MulImpl::NativeX4),
        Spec::mul(DType::I8, MulImpl::NativeX8),
        Spec::mul(DType::I32, MulImpl::Mulsi3),
        Spec::mul(DType::I32, MulImpl::Dim),
    ];
    for base in specs {
        for u in [Unroll::No, Unroll::Auto, Unroll::X64, Unroll::X128] {
            let spec = base.with_unroll(u);
            match run_microbench(spec, 4, 8 * 1024, 99) {
                Ok(_) => {}
                Err(upmem_unleashed::Error::IramOverflow { .. }) if u == Unroll::Auto => {}
                Err(e) => panic!("{}: {e}", spec.name()),
            }
        }
    }
}

/// Random structured programs: `Program::optimize` output is
/// bit-identical to the naive stream — full WRAM image equality after
/// execution — for every subset of passes. The generator emits the
/// shapes the passes target (fusible pairs, marked counter loops,
/// bounded `__mulsi3` calls, nop padding, jumps-to-next) with honest
/// metadata, interleaved with random ALU/memory soup.
#[test]
fn optimizer_is_architecturally_invisible_on_random_programs() {
    forall(
        Config::cases(60),
        |rng| (rng.next_u64(), rng.next_u64() as u8),
        |&(seed, cfg_mask)| {
            let naive = random_program(seed);
            let mut cfg = PassConfig::none();
            for (bit, pass) in ALL_PASSES.into_iter().enumerate() {
                if cfg_mask & (1u8 << bit) != 0 {
                    cfg = cfg.set(pass, true);
                }
            }
            let (opt, _) = naive.optimize(&cfg);
            let run = |p: &Program| {
                let mut d = Dpu::new();
                d.load_program(p).expect("fits IRAM");
                d.launch(1).expect("random programs terminate");
                d
            };
            let d1 = run(&naive);
            let d2 = run(&opt);
            d1.wram.as_slice() == d2.wram.as_slice()
        },
        "optimized stream is bit-identical to naive over random programs",
    );
}

/// Random structured programs on all three interpreter execution tiers
/// (stepped / batched / superblock, `rust/src/dpu/interp.rs`): WRAM
/// images, cycle counts, instruction counts and DMA accounting must be
/// bit-identical — for the naive stream *and* for every random pass
/// subset of its optimized form, so tier equivalence holds on
/// arbitrary post-optimizer shapes (fused condition slots, truncated
/// `mul_step` chains, unrolled bodies), not just emitter output.
#[test]
fn exec_tiers_are_bit_identical_on_random_programs() {
    forall(
        Config::cases(40),
        |rng| (rng.next_u64(), rng.next_u64() as u8),
        |&(seed, cfg_mask)| {
            let naive = random_program(seed);
            let mut cfg = PassConfig::none();
            for (bit, pass) in ALL_PASSES.into_iter().enumerate() {
                if cfg_mask & (1u8 << bit) != 0 {
                    cfg = cfg.set(pass, true);
                }
            }
            let (opt, _) = naive.optimize(&cfg);
            for prog in [&naive, &opt] {
                let run = |tier: ExecTier| {
                    let mut d = Dpu::new();
                    d.set_exec_tier(tier);
                    d.load_program(prog).expect("fits IRAM");
                    let r = d.launch(1).expect("random programs terminate");
                    (r, d)
                };
                let (r0, d0) = run(ExecTier::Stepped);
                for tier in [ExecTier::Batched, ExecTier::Superblock] {
                    let (r1, d1) = run(tier);
                    if r0 != r1 || d0.wram.as_slice() != d1.wram.as_slice() {
                        return false;
                    }
                }
            }
            true
        },
        "all three exec tiers bit-identical (WRAM + LaunchResult) on random programs",
    );
}

/// The framework-built PrIM kernels (reduce / histogram / scan /
/// select) verify element-by-element against their `cpu_ref::prim`
/// host references under *every* random subset of optimizer passes,
/// random shapes (including zero-length and non-power-of-two) and
/// random tasklet counts. The runners return `Err` on any output
/// mismatch, so `is_ok()` is the differential assertion.
#[test]
fn framework_kernels_verify_under_random_pass_subsets() {
    use upmem_unleashed::kernels::{histogram, reduce, scan, select, KernelScratch};
    forall(
        Config::cases(12),
        |rng| {
            let n = rng.range_u64(0, 1200) as usize;
            let tasklets = rng.range_u64(1, 16) as usize;
            (rng.next_u64(), rng.next_u64() as u8, n, tasklets)
        },
        |&(seed, mask, n, tasklets)| {
            let mut cfg = PassConfig::none();
            for (bit, pass) in ALL_PASSES.into_iter().enumerate() {
                if mask & (1u8 << bit) != 0 {
                    cfg = cfg.set(pass, true);
                }
            }
            let mut data_rng = Rng::new(seed);
            let i32s = data_rng.i32_vec(n);
            let bytes = data_rng.u8_vec(n);
            let mut scr = KernelScratch::default();
            reduce::run_reduce_cfg_with(&mut scr, &cfg, tasklets, &i32s).is_ok()
                && histogram::run_histogram_cfg_with(&mut scr, &cfg, tasklets, 256, &bytes).is_ok()
                && scan::run_scan_cfg_with(&mut scr, &cfg, tasklets, &i32s).is_ok()
                && select::run_select_cfg_with(&mut scr, &cfg, tasklets, &i32s).is_ok()
        },
        "PrIM framework kernels verify under random pass subsets",
    );
}

/// Deterministic random-program generator for the differential
/// property above. Single-tasklet, WRAM-only, always terminates.
fn random_program(seed: u64) -> Program {
    let mut rng = Rng::new(seed);
    let mut pb = ProgramBuilder::new();
    let main = pb.new_label("main");
    pb.jump(main); // becomes a jump-to-next when no routine follows
    let routine = if rng.f64() < 0.5 { Some(emit_mulsi3(&mut pb)) } else { None };
    pb.bind(main);

    // Working registers r0..r7; r10/r11 reserved as loop pointers.
    fn alu_soup(rng: &mut Rng, pb: &mut ProgramBuilder, n: u64) {
        for _ in 0..n {
            let rd = Reg(rng.range_u64(0, 7) as u8);
            let ra = Reg(rng.range_u64(0, 7) as u8);
            let imm = rng.range_i64(-64, 64) as i32;
            match rng.range_u64(0, 5) {
                0 => pb.add(rd, ra, imm),
                1 => pb.sub(rd, ra, imm),
                2 => pb.xor(rd, ra, imm),
                3 => {
                    let sh = rng.range_i64(0, 7) as i32;
                    pb.lsl(rd, ra, sh)
                }
                4 => pb.or(rd, ra, imm),
                _ => pb.and(rd, ra, imm),
            }
        }
    }

    let blocks = rng.range_u64(2, 5);
    for block in 0..blocks {
        let n = rng.range_u64(1, 6);
        alu_soup(&mut rng, &mut pb, n);
        if rng.f64() < 0.5 {
            pb.nop();
        }
        // A fusible pair: op + zero-compare jump over a poison write.
        if rng.f64() < 0.7 {
            let skip = pb.new_label(&format!("skip{block}"));
            let r = Reg(rng.range_u64(0, 7) as u8);
            pb.and(r, r, 1);
            pb.jcmp(CmpCond::Eq, r, Src::Zero, skip);
            pb.add(r, r, 100);
            pb.bind(skip);
        }
        // A shift-add pair over a dead temp.
        if rng.f64() < 0.7 {
            let t = Reg(6);
            let d = Reg(rng.range_u64(0, 5) as u8);
            pb.lsl(t, Reg(rng.range_u64(0, 5) as u8), rng.range_i64(0, 8) as i32);
            pb.add(d, d, Src::Reg(t));
            pb.move_(t, 0); // kill the temp so fusion liveness holds either way
        }
        // A bounded-multiplier call.
        if let Some(mulsi3) = routine {
            if rng.f64() < 0.6 {
                let bits = rng.range_u64(1, 12) as u8;
                let mult = (rng.next_u64() as u32) & ((1u32 << bits) - 1);
                pb.move_(ARG_A, rng.next_u64() as u32 as i32);
                pb.move_(ARG_B, mult as i32);
                pb.call_mul_bounded(LINK, mulsi3, bits);
                pb.add(Reg(4), RESULT, Src::Reg(Reg(4)));
                // The bounded-call contract leaves r2 (and the link)
                // unspecified; equalize r2 so the final stores compare.
                pb.move_(Reg(2), 0);
            }
        }
        // A marked counter loop over a WRAM byte window.
        if rng.f64() < 0.8 {
            let trip = *rng.choose(&[4u32, 8, 16]);
            let factor = *rng.choose(&[1u32, 2, 4]);
            let ptr = Reg(10);
            let pend = Reg(11);
            let base = 0x200 + 0x40 * block as i32;
            pb.move_(ptr, base);
            pb.add(pend, ptr, trip as i32);
            let (head, lm) =
                pb.unrollable_loop(&format!("loop{block}"), trip, factor.min(trip));
            pb.lbu(Reg(0), ptr, 0);
            pb.add(Reg(0), Reg(0), rng.range_i64(1, 9) as i32);
            pb.sb(ptr, 0, Reg(0));
            pb.unrollable_latch(lm, head, &[(ptr, 1)], CmpCond::Ltu, ptr, Src::Reg(pend));
        }
    }
    // Make every working register observable.
    for r in 0..8u8 {
        pb.move_(Reg(12), 0x100 + 4 * r as i32);
        pb.sw(Reg(12), 0, Reg(r));
    }
    pb.stop();
    pb.build().expect("generator emits bound labels")
}

/// Seeds differ ⇒ data differs but cycle counts of data-independent
/// kernels do not (NI path), while the data-dependent `__mulsi3` path
/// may differ.
#[test]
fn data_independence_of_ni_kernels() {
    let mut rng = Rng::new(1);
    let spec = Spec::mul(DType::I8, MulImpl::NativeX8);
    let c: Vec<u64> = (0..3)
        .map(|_| run_microbench(spec, 8, 16 * 1024, rng.next_u64()).unwrap().launch.cycles)
        .collect();
    assert!(c.windows(2).all(|w| w[0] == w[1]), "NI kernels are data-independent: {c:?}");
}
