//! Cross-layer integration: the rust DPU simulator, the AOT-compiled
//! JAX/Pallas artifacts (via PJRT) and the native CPU reference must
//! all agree numerically.
//!
//! These tests skip (with a notice) when `make artifacts` has not run,
//! so `cargo test` stays green on a fresh checkout; CI runs
//! `make artifacts` first.

use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::encode;
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant};
use upmem_unleashed::coordinator::GemvCoordinator;
use upmem_unleashed::runtime::{
    artifacts_available, BsdpOracle, GemvOracle, MlpOracle, XlaRuntime, ORACLE_COLS, ORACLE_ROWS,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn xla_gemv_oracle_matches_host_reference() {
    require_artifacts!();
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let oracle = GemvOracle::load(&rt).expect("artifact loads");
    let mut rng = Rng::new(71);
    let m = rng.i8_vec(ORACLE_ROWS * ORACLE_COLS);
    let x = rng.i8_vec(ORACLE_COLS);
    let y = oracle.gemv(&m, &x).expect("executes");
    let want = gemv_ref(
        GemvShape { rows: ORACLE_ROWS as u32, cols: ORACLE_COLS as u32 },
        &m,
        &x,
    );
    assert_eq!(y, want);
}

#[test]
fn simulator_fleet_agrees_with_xla_oracle() {
    require_artifacts!();
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let oracle = GemvOracle::load(&rt).expect("artifact loads");
    let mut rng = Rng::new(72);
    let m = rng.i8_vec(ORACLE_ROWS * ORACLE_COLS);
    let x = rng.i8_vec(ORACLE_COLS);

    // Same matrix through the simulated DPU fleet.
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
    c.preload_matrix(ORACLE_ROWS as u32, ORACLE_COLS as u32, &m).unwrap();
    let (y_sim, _) = c.gemv(&x).unwrap();

    let y_xla = oracle.gemv(&m, &x).expect("executes");
    assert_eq!(y_sim, y_xla, "DPU simulator vs AOT XLA artifact");
}

#[test]
fn bsdp_artifact_matches_simulator_and_reference() {
    require_artifacts!();
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let oracle = BsdpOracle::load(&rt).expect("artifact loads");
    let (rows, cols) = (256usize, 2048usize);
    let mut rng = Rng::new(73);
    let m = rng.i4_vec(rows * cols);
    let x = rng.i4_vec(cols);
    // Encode with the rust encoder (layout shared with python ref.py).
    let mut m_planes = Vec::new();
    for r in m.chunks_exact(cols) {
        m_planes.extend(encode::bitplane_encode_i4(r));
    }
    let x_planes = encode::bitplane_encode_i4(&x);
    let y_xla = oracle.gemv(&m_planes, &x_planes, rows).expect("executes");
    let want = gemv_ref(GemvShape { rows: rows as u32, cols: cols as u32 }, &m, &x);
    assert_eq!(y_xla, want, "Pallas BSDP artifact vs host reference");

    // And the simulated DPU fleet on the same data.
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut c = GemvCoordinator::new(sys, set, GemvVariant::I4Bsdp, 8);
    c.preload_matrix(rows as u32, cols as u32, &m).unwrap();
    let (y_sim, _) = c.gemv(&x).unwrap();
    assert_eq!(y_sim, y_xla, "DPU simulator vs Pallas BSDP artifact");
}

#[test]
fn mlp_artifact_matches_rust_fixed_point_pipeline() {
    require_artifacts!();
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let oracle = MlpOracle::load(&rt).expect("artifact loads");
    let mut rng = Rng::new(74);
    let w1 = rng.i8_vec(1024 * 1024);
    let w2 = rng.i8_vec(64 * 1024);
    let x = rng.i8_vec(1024);
    let got = oracle.forward(&w1, &w2, &x).expect("executes");

    // Rust-side fixed-point pipeline (the serving example's math).
    let h = gemv_ref(GemvShape { rows: 1024, cols: 1024 }, &w1, &x);
    let h8: Vec<i8> = h
        .iter()
        .map(|&v| (v.max(0) >> 8).clamp(-128, 127) as i8)
        .collect();
    let want = gemv_ref(GemvShape { rows: 64, cols: 1024 }, &w2, &h8);
    assert_eq!(got, want);
}

#[test]
fn xla_cpu_comparator_reports_throughput() {
    require_artifacts!();
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let oracle = GemvOracle::load(&rt).expect("artifact loads");
    let gops = oracle.measure_gops(3, 99).expect("measures");
    assert!(gops > 0.01, "gops = {gops}");
    eprintln!("XLA CPU INT8 GEMV comparator: {gops:.2} GOPS");
}
