//! Data-plane contracts (ISSUE 5 acceptance pins):
//!
//! 1. Sharded GEMV output is **bit-identical** to the unsharded
//!    [`GemvCoordinator`] path for every placement policy — placement
//!    moves bytes, never results.
//! 2. `NumaBalanced` modeled push+broadcast throughput beats `Linear`
//!    on the paper-server topology under the cross-socket penalty, and
//!    its boot-to-boot consistency is strictly better (the Fig. 11
//!    variability story at the data-plane layer).
//! 3. Rebalancing after `mark_faulty` preserves results while
//!    re-transferring **only** the remapped shard's block.
//!
//! Plus the serving integration: a sharded replica behind the generic
//! `GemvServer` / `ReplicaPool` router, and the socket-pinned eager
//! scatter's equivalence + deterministic error contracts.

use upmem_unleashed::alloc::NumaAwareAllocator;
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::coordinator::server::default_batcher;
use upmem_unleashed::coordinator::{GemvCoordinator, GemvServer, ReplicaPool};
use upmem_unleashed::dpu::MRAM_BYTES;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant};
use upmem_unleashed::plane::{
    placement_rates, ChannelInterleaved, Linear, NumaBalanced, PlacementPolicy, ScatterChunk,
    ShardMap, ShardedGemvCoordinator,
};
use upmem_unleashed::transfer::model::TransferModel;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::util::stats::Summary;
use upmem_unleashed::Error;

fn sharded(
    topo: SystemTopology,
    policy: &dyn PlacementPolicy,
    n_shards: usize,
    ranks_per_shard: usize,
    variant: GemvVariant,
    nr_tasklets: usize,
) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(topo, AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(policy, n_shards, ranks_per_shard).unwrap();
    let map = ShardMap::new(sets, policy.name()).unwrap();
    ShardedGemvCoordinator::new(sys, map, variant, nr_tasklets)
}

#[test]
fn sharded_gemv_is_bit_identical_to_flat_for_all_policies() {
    let (rows, cols) = (192u32, 1024u32);
    let mut rng = Rng::new(81);
    let m = rng.i8_vec((rows * cols) as usize);
    let x = rng.i8_vec(cols as usize);

    // The unsharded reference path: one flat 128-DPU set.
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut flat = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
    flat.preload_matrix(rows, cols, &m).unwrap();
    let (y_flat, _) = flat.gemv(&x).unwrap();
    assert_eq!(y_flat, gemv_ref(GemvShape { rows, cols }, &m, &x));

    let policies: Vec<Box<dyn PlacementPolicy>> = vec![
        Box::new(Linear { boot_seed: 3 }),
        Box::new(ChannelInterleaved),
        Box::new(NumaBalanced),
    ];
    for policy in &policies {
        let mut c =
            sharded(SystemTopology::pristine(), policy.as_ref(), 2, 1, GemvVariant::I8Opt, 8);
        let rep = c.preload_matrix(rows, cols, &m).unwrap();
        assert_eq!(rep.bytes, rows as u64 * cols as u64);
        assert!(rep.seconds > 0.0);
        let (y, t) = c.gemv(&x).unwrap();
        assert_eq!(y, y_flat, "policy {} changed GEMV results", policy.name());
        assert!(t.broadcast_s > 0.0 && t.compute_s > 0.0 && t.gather_s > 0.0);
    }
}

#[test]
fn sharded_bsdp_matches_reference() {
    let (rows, cols) = (128u32, 2048u32);
    let mut rng = Rng::new(82);
    let m = rng.i4_vec((rows * cols) as usize);
    let x = rng.i4_vec(cols as usize);
    let mut c = sharded(SystemTopology::pristine(), &NumaBalanced, 2, 1, GemvVariant::I4Bsdp, 8);
    c.preload_matrix(rows, cols, &m).unwrap();
    let (y, _) = c.gemv(&x).unwrap();
    assert_eq!(y, gemv_ref(GemvShape { rows, cols }, &m, &x));
}

/// Modeled scatter + broadcast-tree throughput of a 4×2-rank sharded
/// fleet under `policy` — model only, no DPU simulation; rates through
/// the plane's shared [`placement_rates`] helper (one definition for
/// the bench's CI-gated rows and this acceptance pin).
fn modeled_push_broadcast_gbps(topo: &SystemTopology, policy: &dyn PlacementPolicy) -> f64 {
    let model = TransferModel::default();
    let mut alloc = NumaAwareAllocator::new(topo.clone());
    let p = policy.place(&mut alloc, 4, 2).unwrap();
    let (_scatter, _tree, combined) = placement_rates(topo, &model, &p, 64 << 20, 4 << 20);
    combined
}

#[test]
fn numa_balanced_beats_linear_and_is_strictly_more_consistent() {
    let topo = SystemTopology::paper_server();
    let boots = 10u64;
    let numa: Vec<f64> =
        (0..boots).map(|_| modeled_push_broadcast_gbps(&topo, &NumaBalanced)).collect();
    let linear: Vec<f64> = (0..boots)
        .map(|b| modeled_push_broadcast_gbps(&topo, &Linear { boot_seed: b }))
        .collect();
    for (l, n) in linear.iter().zip(&numa) {
        assert!(n >= l, "NumaBalanced ({n} GB/s) must be ≥ Linear ({l} GB/s) on every boot");
    }
    let sn = Summary::of(&numa);
    let sl = Summary::of(&linear);
    assert!(
        sn.mean / sl.mean > 1.8,
        "placement gain {} below the paper-scale band (numa {} vs linear {})",
        sn.mean / sl.mean,
        sn.mean,
        sl.mean
    );
    // Tail consistency: the balanced plane is boot-invariant; the
    // placement-blind baseline swings GB/s across boots.
    assert!(sl.spread() > 0.5, "baseline should vary across boots: {linear:?}");
    assert!(
        sn.spread() < sl.spread(),
        "NumaBalanced spread {} must be strictly below Linear's {}",
        sn.spread(),
        sl.spread()
    );
}

#[test]
fn rebalance_after_fault_preserves_results_with_delta_transfer_only() {
    let (rows, cols) = (192u32, 1024u32);
    let mut rng = Rng::new(91);
    let m = rng.i8_vec((rows * cols) as usize);
    let x = rng.i8_vec(cols as usize);
    let mut c = sharded(SystemTopology::pristine(), &NumaBalanced, 2, 1, GemvVariant::I8Opt, 8);
    let rep = c.preload_matrix(rows, cols, &m).unwrap();
    let rb = cols as u64; // INT8: row stride == cols
    assert_eq!(rep.bytes, rows as u64 * rb);
    let (y0, _) = c.gemv(&x).unwrap();
    assert_eq!(y0, gemv_ref(GemvShape { rows, cols }, &m, &x));

    let victim = c.map().shards[1].set.dpus[17];
    let shard1_rows = c.map().shards[1].rows;
    let shard0_dpus = c.map().shards[0].set.nr_dpus();
    let shard1_dpus = c.map().shards[1].set.nr_dpus();
    let moved = c.mark_faulty_and_rebalance(victim).unwrap();
    assert_eq!(
        moved,
        shard1_rows as u64 * rb,
        "delta transfer must be exactly the remapped shard's block"
    );
    assert!(moved < rep.bytes, "a rebalance must not re-push the whole matrix");
    assert_eq!(c.map().shards[0].set.nr_dpus(), shard0_dpus, "shard 0 untouched");
    assert_eq!(c.map().shards[1].set.nr_dpus(), shard1_dpus - 1);
    assert_eq!(c.map().shard_of_dpu(victim), None);
    assert!(c.sys.topology().is_faulty(victim));

    let (y1, _) = c.gemv(&x).unwrap();
    assert_eq!(y1, y0, "rebalance must preserve results bit-exactly");

    // A second fault in the other shard remaps only that shard.
    let victim2 = c.map().shards[0].set.dpus[3];
    let shard0_rows = c.map().shards[0].rows;
    assert_eq!(c.mark_faulty_and_rebalance(victim2).unwrap(), shard0_rows as u64 * rb);
    let (y2, _) = c.gemv(&x).unwrap();
    assert_eq!(y2, y0);

    // A DPU outside every shard is a fleet-level fault but a plane
    // no-op: nothing to re-transfer.
    assert_eq!(c.mark_faulty_and_rebalance(39 * 64 + 1).unwrap(), 0);
}

#[test]
fn sharded_pipeline_overlaps_and_matches_serial_results() {
    let (rows, cols) = (192u32, 1024u32);
    let mut rng = Rng::new(92);
    let m = rng.i8_vec((rows * cols) as usize);
    let mut c = sharded(SystemTopology::pristine(), &NumaBalanced, 2, 1, GemvVariant::I8Opt, 8);
    c.preload_matrix(rows, cols, &m).unwrap();
    let x1 = rng.i8_vec(cols as usize);
    let x2 = rng.i8_vec(cols as usize);
    let (y1, ta) = c.gemv(&x1).unwrap();
    let (y2, tb) = c.gemv(&x2).unwrap();
    let serial = ta.total() + tb.total();
    let (ys, tp) = c.gemv_pipelined(&[&x1, &x2]).unwrap();
    assert_eq!(ys.len(), 2);
    assert_eq!(ys[0], y1, "pipelining must not change results");
    assert_eq!(ys[1], y2);
    assert!(tp.overlap_s > 0.0, "batch 2's tree should ride under batch 1's compute: {tp:?}");
    assert!(tp.total() < serial, "pipelined wall {} must beat serial {serial}", tp.total());
    assert_eq!(c.gemv_count(), 4);
    assert!(c.last_instrs() > 0 && c.last_max_cycles() > 0);
}

#[test]
fn sharded_replica_serves_through_the_router() {
    let (rows, cols) = (128u32, 1024u32);
    let mut rng = Rng::new(93);
    let m = rng.i8_vec((rows * cols) as usize);

    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(2).unwrap();
    let mut flat = GemvCoordinator::new(sys, set, GemvVariant::I8Opt, 8);
    flat.preload_matrix(rows, cols, &m).unwrap();

    let mut shard = sharded(SystemTopology::pristine(), &NumaBalanced, 2, 1, GemvVariant::I8Opt, 8);
    shard.preload_matrix(rows, cols, &m).unwrap();

    // One flat replica + one sharded replica behind one router: the
    // GemvExecutor seam makes them interchangeable to the server.
    let (s_flat, c_flat) = GemvServer::start(flat, default_batcher(4));
    let (s_shard, c_shard) = GemvServer::start(shard, default_batcher(4));
    let mut pool = ReplicaPool::new(vec![c_flat, c_shard], Policy::RoundRobin);
    for _ in 0..4 {
        let x = rng.i8_vec(cols as usize);
        let resp = pool.call(x.clone()).unwrap();
        assert_eq!(resp.y.unwrap(), gemv_ref(GemvShape { rows, cols }, &m, &x));
        assert!(resp.device_seconds > 0.0);
    }
    assert_eq!(pool.router().dispatched(0), 2);
    assert_eq!(pool.router().dispatched(1), 2);
    assert_eq!(pool.router().outstanding(0) + pool.router().outstanding(1), 0);
    let (_, m1) = s_flat.shutdown();
    let (shard, m2) = s_shard.shutdown();
    assert_eq!(m1.requests + m2.requests, 4);
    assert_eq!(m1.errors + m2.errors, 0);
    assert_eq!(shard.gemv_count(), 2);
}

#[test]
fn socket_pinned_scatter_matches_serial_writes_and_orders_errors() {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).unwrap();
    let all_dpus: Vec<usize> =
        sets.iter().flat_map(|s| s.dpus.iter().copied()).collect();
    let payloads: Vec<Vec<u8>> =
        all_dpus.iter().map(|&d| vec![(d % 251) as u8; 64]).collect();
    let chunks: Vec<ScatterChunk> = all_dpus
        .iter()
        .zip(&payloads)
        .map(|(&dpu, bytes)| ScatterChunk { dpu, mram_addr: 4096, bytes })
        .collect();
    sys.scatter_socket_pinned(&chunks).unwrap();
    for (si, set) in sets.iter().enumerate() {
        for i in [0usize, 17, 63] {
            let dpu_id = set.dpus[i];
            let mut buf = [0u8; 64];
            sys.dpu_of(set, i).mram.read(4096, &mut buf).unwrap();
            assert!(
                buf.iter().all(|&b| b == (dpu_id % 251) as u8),
                "shard {si} dpu {dpu_id} got the wrong bytes"
            );
        }
    }

    // Deterministic error contract: the reported failure is the first
    // failing chunk in argument order, regardless of which socket's
    // worker thread hits it first. Chunk 0 targets the *socket-1*
    // shard, chunk 1 the socket-0 shard — both out of bounds.
    let bad_addr = (MRAM_BYTES - 16) as u32;
    let long = vec![0u8; 64];
    let bad = vec![
        ScatterChunk { dpu: sets[1].dpus[0], mram_addr: bad_addr, bytes: &long },
        ScatterChunk { dpu: sets[0].dpus[0], mram_addr: bad_addr, bytes: &long },
    ];
    match sys.scatter_socket_pinned(&bad) {
        Err(Error::HostAccess { dpu, .. }) => {
            assert_eq!(dpu, sets[1].dpus[0], "first chunk in argument order wins");
        }
        other => panic!("expected HostAccess, got {other:?}"),
    }
}
