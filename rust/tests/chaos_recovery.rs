//! Chaos-plane contracts (ISSUE 7 acceptance pins):
//!
//! 1. **Keystone**: for any seeded [`ChaosPlan`] whose permanent faults
//!    leave ≥1 usable DPU per shard, the self-healing coordinator
//!    serves `y` **bit-identical** to a fault-free run — and replaying
//!    the same seed reproduces the fault sequence, retry counts and
//!    recovery metrics *exactly*, on every [`ExecTier`].
//! 2. Satellite regressions: idempotent double-mark, degenerate
//!    topologies (a shard losing its last DPU, a zero-admitted replica
//!    pool), and a transient fault landing mid-`gemv_pipelined`
//!    between broadcast and launch.

use upmem_unleashed::chaos::{
    ChaosConfig, ChaosInjector, ChaosPlan, ChaosStats, DegradedMode, FaultEvent, RecoveryMetrics,
    SelfHealingCoordinator,
};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::coordinator::server::default_batcher;
use upmem_unleashed::coordinator::{GemvServer, ReplicaPool};
use upmem_unleashed::dpu::ExecTier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant};
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::{Error, ErrorClass};

const ROWS: u32 = 256;
const COLS: u32 = 1024;

fn sharded(tier: ExecTier) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    sys.set_exec_tier(tier);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).unwrap();
    let map = ShardMap::new(sets, NumaBalanced.name()).unwrap();
    ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8)
}

fn test_data() -> (Vec<i8>, Vec<Vec<i8>>) {
    let mut rng = Rng::new(7);
    let m = rng.i8_vec((ROWS * COLS) as usize);
    let xs = (0..3).map(|_| rng.i8_vec(COLS as usize)).collect();
    (m, xs)
}

/// Serve `xs` as two pipelined batches ([x0, x1] then [x2]) — the
/// same call pattern every run in this file uses, so modeled clocks
/// and op sequences line up exactly.
fn serve(c: &mut SelfHealingCoordinator, xs: &[Vec<i8>]) -> Vec<Vec<i32>> {
    let (mut ys, _) = c.gemv_recovered(&[&xs[0], &xs[1]]).unwrap();
    let (tail, _) = c.gemv_recovered(&[&xs[2]]).unwrap();
    ys.extend(tail);
    ys
}

fn fault_free_reference(xs: &[Vec<i8>], m: &[i8]) -> Vec<Vec<i32>> {
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, m).unwrap();
    let (mut ys, _) = c.gemv_pipelined(&[&xs[0], &xs[1]]).unwrap();
    let (tail, _) = c.gemv_pipelined(&[&xs[2]]).unwrap();
    ys.extend(tail);
    for (y, x) in ys.iter().zip(xs) {
        assert_eq!(y, &gemv_ref(GemvShape { rows: ROWS, cols: COLS }, m, x));
    }
    ys
}

/// Everything a seeded chaos run produces; `PartialEq` fields compare
/// exactly (the f64s are products of identical deterministic
/// arithmetic when runs really replay).
struct ChaosRun {
    ys: Vec<Vec<i32>>,
    stats: ChaosStats,
    metrics: RecoveryMetrics,
    modeled_end: f64,
}

/// One self-healing serving run under the plan generated from `seed`:
/// victims are drawn from the middle of each shard so any generated
/// death set leaves ≥1 usable DPU per shard (the keystone's
/// precondition).
fn chaos_run(seed: u64, tier: ExecTier, m: &[i8], xs: &[Vec<i8>]) -> ChaosRun {
    let mut c = sharded(tier);
    c.preload_matrix(ROWS, COLS, m).unwrap();
    let victims: Vec<usize> = (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
    let cfg = ChaosConfig { ops: 8, ..ChaosConfig::default() };
    let plan = ChaosPlan::generate(seed, &cfg, &victims);
    assert_eq!(plan.dead_dpus().len(), 2, "default config kills two victims");
    c.sys.install_chaos(ChaosInjector::new(plan));
    let mut sh = SelfHealingCoordinator::new(c);
    let ys = serve(&mut sh, xs);
    let metrics = sh.metrics().clone();
    let mut c = sh.into_inner();
    let inj = c.sys.take_chaos().unwrap();
    // Accounting contract: every planned one-shot event actually fired
    // during the run — injected == fired, nothing silently dropped.
    assert!(
        inj.unfired().is_empty(),
        "seed {seed}: planned events never applied: {:?}",
        inj.unfired()
    );
    let stats = inj.stats().clone();
    let modeled_end = c.sys.modeled_now();
    ChaosRun { ys, stats, metrics, modeled_end }
}

#[test]
fn keystone_seeded_faults_serve_bit_identical_results() {
    let (m, xs) = test_data();
    let reference = fault_free_reference(&xs, &m);
    for seed in [11u64, 23, 47] {
        let a = chaos_run(seed, ExecTier::Superblock, &m, &xs);
        assert_eq!(a.ys, reference, "seed {seed}: faults changed served results");
        // Every planned death activated (all land at op ≤ 8, the run
        // spans ≥ 12 ops) and was quarantined through the rebalance.
        assert_eq!(a.stats.dpu_deaths, 2, "seed {seed}");
        assert_eq!(
            a.stats.corruptions_applied(),
            0,
            "seed {seed}: the default config plans zero corruption"
        );
        assert_eq!(a.metrics.quarantined.len(), 2, "seed {seed}");
        assert_eq!(a.metrics.rebalances, 2, "seed {seed}");
        assert!(a.metrics.retries >= 2, "seed {seed}: each death costs ≥1 retry");
        assert!(a.metrics.recovery_s > 0.0, "seed {seed}: recovery latency is modeled");

        // Same seed → the fault sequence, retry counts and recovery
        // metrics replay *exactly*.
        let b = chaos_run(seed, ExecTier::Superblock, &m, &xs);
        assert_eq!(a.ys, b.ys, "seed {seed}");
        assert_eq!(a.stats, b.stats, "seed {seed}: injector stats must replay exactly");
        assert_eq!(a.metrics, b.metrics, "seed {seed}: recovery metrics must replay exactly");
        assert_eq!(a.modeled_end, b.modeled_end, "seed {seed}: modeled clock must replay exactly");
    }
    // Different seeds draw different plans.
    let victims: Vec<usize> = (0..16).collect();
    let cfg = ChaosConfig { ops: 8, ..ChaosConfig::default() };
    assert_ne!(
        ChaosPlan::generate(11, &cfg, &victims),
        ChaosPlan::generate(23, &cfg, &victims)
    );
}

#[test]
fn keystone_holds_across_all_exec_tiers() {
    let (m, xs) = test_data();
    let reference = chaos_run(11, ExecTier::Stepped, &m, &xs);
    assert_eq!(reference.ys, fault_free_reference(&xs, &m));
    for tier in [ExecTier::Batched, ExecTier::Superblock] {
        let run = chaos_run(11, tier, &m, &xs);
        assert_eq!(run.ys, reference.ys, "{} diverged on results", tier.name());
        assert_eq!(run.stats, reference.stats, "{} diverged on fault sequence", tier.name());
        assert_eq!(run.metrics, reference.metrics, "{} diverged on recovery", tier.name());
        assert_eq!(
            run.modeled_end,
            reference.modeled_end,
            "{} diverged on the modeled clock",
            tier.name()
        );
    }
}

#[test]
fn transient_faults_only_recover_to_exact_results_with_retries() {
    let (m, xs) = test_data();
    let reference = fault_free_reference(&xs, &m);
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::TransientTransfer { at: 1 },
        FaultEvent::TransientLaunch { at: 5 },
        FaultEvent::TransientLaunch { at: 9 },
    ])));
    let mut sh = SelfHealingCoordinator::new(c);
    let ys = serve(&mut sh, &xs);
    assert_eq!(ys, reference);
    let metrics = sh.metrics();
    assert_eq!(metrics.transient_errors, 3);
    assert_eq!(metrics.retries, 3, "each one-shot transient costs exactly one retry");
    assert!(metrics.quarantined.is_empty(), "below the strike threshold nothing quarantines");
    assert!(metrics.backoff_s > 0.0, "retries back off on the modeled clock");
    assert_eq!(sh.inner.sys.chaos().unwrap().stats().launch_errors, 2);
    assert_eq!(sh.inner.sys.chaos().unwrap().stats().transfer_errors, 1);
}

#[test]
fn straggler_window_stretches_modeled_time_but_not_results() {
    let (m, xs) = test_data();
    let mut free = sharded(ExecTier::Superblock);
    free.preload_matrix(ROWS, COLS, &m).unwrap();
    let (ys_free, t_free) = free.gemv_pipelined(&[&xs[0], &xs[1]]).unwrap();

    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::Straggler { from: 1, to: 100, socket: 0, factor: 4.0 },
    ])));
    let (ys, t) = c.gemv_pipelined(&[&xs[0], &xs[1]]).unwrap();
    assert_eq!(ys, ys_free, "stragglers stretch time, never bits");
    assert!(
        t.compute_s > t_free.compute_s,
        "socket-0 shard compute must stretch: {} vs {}",
        t.compute_s,
        t_free.compute_s
    );
    assert!(c.sys.chaos().unwrap().stats().straggled_ops > 0);
}

#[test]
fn transient_fault_mid_pipeline_is_typed_and_recoverable() {
    // Op arithmetic: one batch over two shards consults broadcasts at
    // ops 1–2 and launches at ops 3–4, so `at: 3` lands exactly
    // *between* the broadcast stage and the first launch.
    let (m, xs) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::TransientLaunch { at: 3 },
    ])));
    let err = c.gemv_pipelined(&[&xs[0]]).unwrap_err();
    match &err {
        Error::LaunchFailed { site, transient, .. } => {
            assert!(*transient);
            assert!(site.dpu.is_some() && site.rank.is_some() && site.socket.is_some());
        }
        other => panic!("expected a typed LaunchFailed, got {other:?}"),
    }
    assert_eq!(err.class(), ErrorClass::Transient);

    // The self-healing wrapper turns the same plan into an exact serve.
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::TransientLaunch { at: 3 },
    ])));
    let mut sh = SelfHealingCoordinator::new(c);
    let (ys, _) = sh.gemv_recovered(&[&xs[0]]).unwrap();
    assert_eq!(ys[0], gemv_ref(GemvShape { rows: ROWS, cols: COLS }, &m, &xs[0]));
    assert_eq!(sh.metrics().retries, 1);
}

#[test]
fn double_mark_and_rebalance_is_a_noop() {
    let (m, _) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let victim = c.map().shards[1].set.dpus[17];
    let moved = c.mark_faulty_and_rebalance(victim).unwrap();
    assert!(moved > 0);
    let dpus_after: Vec<usize> = c.map().shards[1].set.dpus.clone();
    let clock_after = c.sys.modeled_now();
    // Second mark of the same DPU: no second rebalance, no transfer,
    // no clock movement, no map change.
    assert_eq!(c.mark_faulty_and_rebalance(victim).unwrap(), 0);
    assert_eq!(c.map().shards[1].set.dpus, dpus_after);
    assert_eq!(c.sys.modeled_now(), clock_after);
    assert!(c.sys.topology().is_faulty(victim));
    // And the fleet-level mark alone is idempotent too.
    assert!(!c.sys.mark_faulty(victim), "second fleet-level mark reports no-op");
}

/// Kill every DPU of shard 1. Under the default `RetryUntilExact` the
/// run must end in the typed "last usable DPU" error — never a silent
/// partial result.
#[test]
fn shard_losing_every_dpu_fails_loudly_by_default() {
    let (m, xs) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let doomed: Vec<FaultEvent> = c.map().shards[1]
        .set
        .dpus
        .iter()
        .map(|&dpu| FaultEvent::DpuDeath { at: 1, dpu })
        .collect();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(doomed)));
    let mut sh = SelfHealingCoordinator::new(c);
    let err = sh.gemv_recovered(&[&xs[0]]).unwrap_err();
    assert_eq!(err.class(), ErrorClass::Permanent);
    assert!(
        err.to_string().contains("last usable DPU"),
        "want the typed coverage error, got: {err}"
    );
    // 63 quarantines succeeded before the coverage ran out.
    assert_eq!(sh.metrics().quarantined.len(), 63);
}

/// Same doomed shard under the explicit partial opt-in: the shard is
/// retired, its rows zero-fill, and the surviving shard keeps serving
/// bit-exactly.
#[test]
fn shard_losing_every_dpu_degrades_only_on_explicit_optin() {
    let (m, xs) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let shard0_rows = c.map().shards[0].rows as usize;
    let doomed: Vec<FaultEvent> = c.map().shards[1]
        .set
        .dpus
        .iter()
        .map(|&dpu| FaultEvent::DpuDeath { at: 1, dpu })
        .collect();
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(doomed)));
    let mut sh = SelfHealingCoordinator::new(c).with_mode(DegradedMode::PartialZeroFill);
    let (ys, _) = sh.gemv_recovered(&[&xs[0]]).unwrap();
    let full = gemv_ref(GemvShape { rows: ROWS, cols: COLS }, &m, &xs[0]);
    assert_eq!(&ys[0][..shard0_rows], &full[..shard0_rows], "surviving shard stays exact");
    assert!(ys[0][shard0_rows..].iter().all(|&v| v == 0), "lost shard's rows zero-fill");
    assert_eq!(sh.inner.retired_shards(), 1);
    assert!(sh.inner.is_retired(1));
    assert_eq!(sh.metrics().degraded_batches, 1);
    // The next batch serves degraded without further recovery work.
    let retries = sh.metrics().retries;
    let (ys2, _) = sh.gemv_recovered(&[&xs[1]]).unwrap();
    assert!(ys2[0][shard0_rows..].iter().all(|&v| v == 0));
    assert_eq!(sh.metrics().retries, retries, "a retired shard costs no more retries");
}

#[test]
fn replica_pool_with_no_admitted_replicas_degrades_cleanly() {
    let (m, _) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let (server, client) = GemvServer::start(c, default_batcher(2));
    let mut pool = ReplicaPool::new(vec![client], Policy::LeastOutstanding);
    pool.evict(0);
    assert!(pool.try_submit(vec![0i8; COLS as usize]).is_none());
    assert!(pool.call(vec![0i8; COLS as usize]).is_none(), "no panic, no hang: just None");
    // Re-admission restores service.
    pool.readmit(0);
    let mut rng = Rng::new(9);
    let x = rng.i8_vec(COLS as usize);
    let resp = pool.call(x.clone()).unwrap();
    assert_eq!(resp.y.unwrap(), gemv_ref(GemvShape { rows: ROWS, cols: COLS }, &m, &x));
    server.shutdown();
}
