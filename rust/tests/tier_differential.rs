//! Execution-tier differential contract: the stepped, batched and
//! superblock issue loops (`rust/src/dpu/interp.rs`, selected by
//! `ExecTier`) must produce **bit-identical** outcomes on the full
//! kernel matrix — every `LaunchResult` (cycles, instrs, DMA bytes),
//! per-tasklet timed cycles, kernel outputs (the runners verify
//! element-by-element against the host reference on every tier), full
//! WRAM images, and, on the fault path, the same `Error` for a
//! mid-fleet fault with identical survivor state. (The faulting DPU's
//! own post-fault memory is deliberately *not* compared: it is
//! tier-defined — see the carve-out in `rust/src/dpu/interp.rs` docs.)
//!
//! The stepped path is the reference; `kernel_properties.rs` covers
//! random programs, `interp.rs` unit tests cover the scheduling-shape
//! corpus and in-window fault ordering. This file covers the paper's
//! kernels: arith × `MulImpl` × `Unroll`, the BSDP dot variants, and
//! all four GEMV variants (plus the DMA-double-buffered stream, whose
//! `ldma_nb`/`dma_wait` pair exercises non-blocking DMA inside
//! superblock windows).

use upmem_unleashed::dpu::{assemble, Dpu, ExecTier};
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::arith::{
    run_microbench_cfg_with, DType, MulImpl, Spec, Unroll,
};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench_cfg_with, DotVariant};
use upmem_unleashed::kernels::gemv::{run_gemv_dpu_cfg_on, GemvShape, GemvVariant};
use upmem_unleashed::kernels::KernelScratch;
use upmem_unleashed::opt::PassConfig;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::Error;

const FAST_TIERS: [ExecTier; 2] = [ExecTier::Batched, ExecTier::Superblock];

/// Everything a single-DPU kernel run can influence.
#[derive(PartialEq, Debug)]
struct Snapshot {
    launch: upmem_unleashed::dpu::LaunchResult,
    tasklet_cycles: Vec<u32>,
    wram: Vec<u8>,
}

#[test]
fn arith_matrix_is_tier_invariant() {
    let specs: Vec<Spec> = vec![
        Spec::add(DType::I8),
        Spec::add(DType::I32),
        Spec::mul(DType::I8, MulImpl::Mulsi3),
        Spec::mul(DType::I8, MulImpl::Native),
        Spec::mul(DType::I8, MulImpl::NativeX4),
        Spec::mul(DType::I8, MulImpl::NativeX8),
        Spec::mul(DType::I32, MulImpl::Mulsi3),
        Spec::mul(DType::I32, MulImpl::Dim),
    ];
    for base in specs {
        for u in [Unroll::No, Unroll::Auto, Unroll::X64, Unroll::X128] {
            let spec = base.with_unroll(u);
            for tasklets in [4usize, 16] {
                let run = |tier: ExecTier| -> Option<Snapshot> {
                    let mut scr = KernelScratch::default();
                    scr.dpu.set_exec_tier(tier);
                    match run_microbench_cfg_with(
                        &mut scr,
                        spec,
                        &spec.default_passes(),
                        tasklets,
                        8 * 1024,
                        99,
                    ) {
                        // The runner has already verified every output
                        // element against the host reference.
                        Ok(o) => Some(Snapshot {
                            launch: o.launch,
                            tasklet_cycles: o.tasklet_cycles,
                            wram: scr.dpu.wram.as_slice().to_vec(),
                        }),
                        // `Unroll::Auto` may overfill IRAM — the
                        // paper's linker error, identical per tier.
                        Err(Error::IramOverflow { .. }) if u == Unroll::Auto => None,
                        Err(e) => panic!("{} ({tasklets}T): {e}", spec.name()),
                    }
                };
                let reference = run(ExecTier::Stepped);
                for tier in FAST_TIERS {
                    assert_eq!(
                        reference,
                        run(tier),
                        "{} ({tasklets}T) diverged on {}",
                        spec.name(),
                        tier.name()
                    );
                }
            }
        }
    }
}

#[test]
fn bsdp_dot_variants_are_tier_invariant() {
    for variant in [
        DotVariant::NativeBaseline,
        DotVariant::NativeMulsi3,
        DotVariant::NativeOptimized,
        DotVariant::Bsdp,
    ] {
        for tasklets in [4usize, 16] {
            let run = |tier: ExecTier| -> (Snapshot, i32) {
                let mut scr = KernelScratch::default();
                scr.dpu.set_exec_tier(tier);
                let o = run_dot_microbench_cfg_with(
                    &mut scr,
                    variant,
                    &PassConfig::all(),
                    tasklets,
                    8 * 2048,
                    7,
                )
                .expect("verified dot run");
                (
                    Snapshot {
                        launch: o.launch,
                        tasklet_cycles: o.tasklet_cycles,
                        wram: scr.dpu.wram.as_slice().to_vec(),
                    },
                    o.dot,
                )
            };
            let reference = run(ExecTier::Stepped);
            for tier in FAST_TIERS {
                assert_eq!(
                    reference,
                    run(tier),
                    "{variant:?} ({tasklets}T) diverged on {}",
                    tier.name()
                );
            }
        }
    }
}

#[test]
fn gemv_variants_are_tier_invariant() {
    let rows = 16u32;
    let mut rng = Rng::new(4242);
    let m8 = rng.i8_vec((rows * 1024) as usize);
    let x8 = rng.i8_vec(1024);
    let m4 = rng.i4_vec((rows * 2048) as usize);
    let x4 = rng.i4_vec(2048);
    let i8_shape = GemvShape { rows, cols: 1024 };
    let i4_shape = GemvShape { rows, cols: 2048 };
    let cases: Vec<(GemvVariant, PassConfig, usize)> = vec![
        (GemvVariant::I8Baseline, GemvVariant::I8Baseline.default_passes(), 16),
        (GemvVariant::I8Mulsi3, GemvVariant::I8Mulsi3.default_passes(), 16),
        (GemvVariant::I8Opt, GemvVariant::I8Opt.default_passes(), 16),
        (GemvVariant::I4Bsdp, GemvVariant::I4Bsdp.default_passes(), 16),
        // All passes incl. DMA double-buffering: `ldma_nb`/`dma_wait`
        // inside superblock windows (≤ 8 tasklets by WRAM layout).
        (GemvVariant::I8Opt, PassConfig::all(), 8),
    ];
    for (variant, cfg, tasklets) in cases {
        let (shape, m, x) = if variant == GemvVariant::I4Bsdp {
            (i4_shape, &m4, &x4)
        } else {
            (i8_shape, &m8, &x8)
        };
        let run = |tier: ExecTier| {
            let mut dpu = Dpu::new();
            dpu.set_exec_tier(tier);
            let (y, launch) = run_gemv_dpu_cfg_on(&mut dpu, variant, &cfg, shape, tasklets, m, x)
                .expect("gemv run");
            (y, launch, dpu.wram.as_slice().to_vec())
        };
        let reference = run(ExecTier::Stepped);
        for tier in FAST_TIERS {
            assert_eq!(
                reference,
                run(tier),
                "{} ({tasklets}T) diverged on {}",
                variant.name(),
                tier.name()
            );
        }
    }
}

/// The framework-built PrIM workload suite (reduce / histogram / scan /
/// select, `rust/src/framework/` + `rust/src/kernels/`) under the full
/// pass pipeline: strict snapshot equality across tiers — LaunchResult,
/// per-tasklet timed cycles, full WRAM image, and the kernel payload.
/// These programs exercise framework-generated shapes the hand kernels
/// don't: double-buffered ping-pong chunk loops, tree combines with
/// four barrier rounds, two chained chunk phases, and data-dependent
/// branchy bodies.
#[test]
fn framework_prim_kernels_are_tier_invariant() {
    use upmem_unleashed::kernels::{histogram, reduce, scan, select};
    let mut rng = Rng::new(0x77);
    let i32s = rng.i32_vec(2000);
    let bytes = rng.u8_vec(5000);
    for tasklets in [3usize, 16] {
        let cfg = PassConfig::all();
        type Payload = (Snapshot, Vec<i32>);
        let kernels: Vec<(&str, Box<dyn Fn(ExecTier) -> Payload + '_>)> = vec![
            (
                "reduce",
                Box::new(|tier| {
                    let mut scr = KernelScratch::default();
                    scr.dpu.set_exec_tier(tier);
                    let o = reduce::run_reduce_cfg_with(&mut scr, &cfg, tasklets, &i32s)
                        .expect("verified reduce run");
                    (
                        Snapshot {
                            launch: o.launch,
                            tasklet_cycles: o.tasklet_cycles,
                            wram: scr.dpu.wram.as_slice().to_vec(),
                        },
                        vec![o.sum],
                    )
                }),
            ),
            (
                "histogram",
                Box::new(|tier| {
                    let mut scr = KernelScratch::default();
                    scr.dpu.set_exec_tier(tier);
                    let o = histogram::run_histogram_cfg_with(&mut scr, &cfg, tasklets, 256, &bytes)
                        .expect("verified histogram run");
                    (
                        Snapshot {
                            launch: o.launch,
                            tasklet_cycles: o.tasklet_cycles,
                            wram: scr.dpu.wram.as_slice().to_vec(),
                        },
                        o.hist.iter().map(|&v| v as i32).collect(),
                    )
                }),
            ),
            (
                "scan",
                Box::new(|tier| {
                    let mut scr = KernelScratch::default();
                    scr.dpu.set_exec_tier(tier);
                    let o = scan::run_scan_cfg_with(&mut scr, &cfg, tasklets, &i32s)
                        .expect("verified scan run");
                    (
                        Snapshot {
                            launch: o.launch,
                            tasklet_cycles: o.tasklet_cycles,
                            wram: scr.dpu.wram.as_slice().to_vec(),
                        },
                        o.out,
                    )
                }),
            ),
            (
                "select",
                Box::new(|tier| {
                    let mut scr = KernelScratch::default();
                    scr.dpu.set_exec_tier(tier);
                    let o = select::run_select_cfg_with(&mut scr, &cfg, tasklets, &i32s)
                        .expect("verified select run");
                    (
                        Snapshot {
                            launch: o.launch,
                            tasklet_cycles: o.tasklet_cycles,
                            wram: scr.dpu.wram.as_slice().to_vec(),
                        },
                        o.out,
                    )
                }),
            ),
        ];
        for (name, run) in &kernels {
            let reference = run(ExecTier::Stepped);
            for tier in FAST_TIERS {
                assert_eq!(
                    reference,
                    run(tier),
                    "{name} ({tasklets}T) diverged on {}",
                    tier.name()
                );
            }
        }
    }
}

#[test]
fn mid_fleet_fault_is_tier_invariant() {
    // One DPU (set index 37) faults via a host-planted flag; the fleet
    // keeps running (hardware semantics). Fault identity and all
    // surviving DPUs' state must match the stepped reference exactly.
    let prog = assemble(
        "move r0, 0\n\
         lw r0, r0, 8\n\
         jeq r0, 1, @bad\n\
         move r1, 37\n\
         spin:\n\
         sub r1, r1, 1\n\
         jneq r1, 0, @spin\n\
         move r2, id4\n\
         add r2, r2, 64\n\
         sw r2, 0, r1\n\
         stop\n\
         bad:\n\
         fault\n",
    )
    .unwrap();
    let run = |tier: ExecTier| -> (Error, Vec<Vec<u8>>) {
        let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
        sys.set_exec_tier(tier);
        let set = sys.alloc_ranks(2).unwrap();
        sys.load_program(&set, &prog).unwrap();
        sys.dpu_of(&set, 37).wram.store32(8, 1).unwrap();
        let err = sys.launch(&set, 8).unwrap_err();
        let mut survivors = Vec::new();
        for i in [0usize, 36, 38, 127] {
            survivors.push(sys.dpu_of(&set, i).wram.as_slice()[0..192].to_vec());
        }
        (err, survivors)
    };
    let reference = run(ExecTier::Stepped);
    assert!(matches!(reference.0, Error::Fault { .. }), "reference: {}", reference.0);
    for tier in FAST_TIERS {
        assert_eq!(reference, run(tier), "mid-fleet fault diverged on {}", tier.name());
    }
}
