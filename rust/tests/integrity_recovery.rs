//! Data-integrity plane contracts (ISSUE 9 acceptance pins):
//!
//! 1. **Keystone**: every corruption a seeded plan lands in a
//!    matrix-resident block is detected — by an in-PIM scrub diff
//!    against the host golden table or by a verify-after-push readback
//!    — repaired delta-only (exactly the corrupted block re-pushed),
//!    and the served `y` is **bit-identical** to a corruption-free
//!    run. Double runs replay the ys, [`ChaosStats`],
//!    [`IntegrityMetrics`] and the modeled end time *exactly*, on
//!    every [`ExecTier`].
//! 2. An **undetectable-by-construction** plan (WRAM flips in the
//!    window no kernel ever reads) is exercised explicitly: the run
//!    must *report* `undetected() == injected`, never silently pass
//!    it off as clean.
//! 3. Serving integration: [`OpenLoopSim`] schedules scrubs on the
//!    modeled clock, their cost and ledger land in the
//!    [`TrafficReport`], and a strict-scrubbing plain replica is
//!    evicted on its first detection.

use upmem_unleashed::chaos::{
    ChaosConfig, ChaosInjector, ChaosPlan, ChaosStats, FaultEvent, IntegrityMetrics,
    RecoveryMetrics, SelfHealingCoordinator,
};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::dpu::ExecTier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant, GEMV_M};
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::traffic::{
    AdmissionConfig, AdmissionPolicy, ArrivalProcess, DeadlineBatcher, OpenLoopSim, SimConfig,
    TrafficConfig, TrafficPlan, WorkloadMix,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::{Error, ErrorClass};

const ROWS: u32 = 128;
const COLS: u32 = 512;
/// One row per DPU at this shape (128 rows over 2×64 DPUs), so every
/// per-DPU block is exactly one row: `row_bytes(COLS)` bytes.
const BLOCK_BYTES: u64 = 512;
const BATCH: usize = 4;

fn sharded(tier: ExecTier) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    sys.set_exec_tier(tier);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).unwrap();
    let map = ShardMap::new(sets, NumaBalanced.name()).unwrap();
    ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8)
}

fn test_data() -> (Vec<i8>, Vec<Vec<i8>>) {
    let mut rng = Rng::new(7);
    let m = rng.i8_vec((ROWS * COLS) as usize);
    let xs = (0..3).map(|_| rng.i8_vec(COLS as usize)).collect();
    (m, xs)
}

/// The serving pattern every run in this file uses — two pipelined
/// batches with an integrity cycle between them, so scrub cost is
/// interleaved with real traffic and modeled clocks line up exactly
/// across runs.
fn serve(sh: &mut SelfHealingCoordinator, xs: &[Vec<i8>]) -> Vec<Vec<i32>> {
    let (mut ys, _) = sh.gemv_recovered(&[&xs[0], &xs[1]]).unwrap();
    sh.scrub_and_repair().unwrap();
    let (tail, _) = sh.gemv_recovered(&[&xs[2]]).unwrap();
    ys.extend(tail);
    ys
}

fn reference_ys(xs: &[Vec<i8>], m: &[i8]) -> Vec<Vec<i32>> {
    let shape = GemvShape { rows: ROWS, cols: COLS };
    xs.iter().map(|x| gemv_ref(shape, m, x)).collect()
}

/// Everything a seeded integrity run produces; `PartialEq` fields
/// compare exactly (the f64s are products of identical deterministic
/// arithmetic when runs really replay).
struct IntegrityRun {
    ys: Vec<Vec<i32>>,
    stats: ChaosStats,
    metrics: RecoveryMetrics,
    integrity: IntegrityMetrics,
    modeled_end: f64,
}

/// One self-healing run under the corruption plan generated from
/// `seed`: scrub-and-repair cycles drive every planned event through a
/// detection boundary *before* serving, so corruption never reaches a
/// served `y` — which is exactly the operational contract (scrub
/// cadence ahead of traffic).
fn integrity_run(seed: u64, tier: ExecTier, m: &[i8], xs: &[Vec<i8>]) -> IntegrityRun {
    let mut c = sharded(tier);
    c.preload_matrix(ROWS, COLS, m).unwrap();
    let victims: Vec<usize> =
        (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
    let cfg = ChaosConfig {
        ops: 6,
        dpu_deaths: 0,
        transient_launches: 1,
        transient_transfers: 1,
        stragglers: 0,
        mram_bit_flips: 2,
        transfer_corruptions: 1,
        // Clamp the corruption window to one resident block so every
        // draw lands in data a scrub actually covers (the default 1 KB
        // window overhangs this shape's 512 B blocks).
        corrupt_mram_len: BLOCK_BYTES as u32,
        ..ChaosConfig::default()
    };
    let plan = ChaosPlan::generate(seed, &cfg, &victims);
    assert_eq!(plan.corruptions().len(), 3, "seed {seed}: 2 MRAM flips + 1 transfer corruption");
    c.sys.install_chaos(ChaosInjector::new(plan));
    let mut sh = SelfHealingCoordinator::new(c);

    // Integrity cycles until the whole plan has fired (scrub launches
    // and repair pushes tick the op counter, so this terminates), then
    // one confirming cycle: an event that fired during the *last* pass
    // of the loop, against a block already diffed that pass, is caught
    // here. After this, nothing is pending and the fleet is clean.
    while !sh.inner.sys.chaos().unwrap().unfired().is_empty() {
        sh.scrub_and_repair().unwrap();
    }
    sh.scrub_and_repair().unwrap();

    let ys = serve(&mut sh, xs);
    let metrics = sh.metrics().clone();
    let integrity = sh.integrity();
    let mut c = sh.into_inner();
    let inj = c.sys.take_chaos().unwrap();
    assert!(inj.unfired().is_empty(), "seed {seed}: planned events never applied");
    let stats = inj.stats().clone();
    let modeled_end = c.sys.modeled_now();
    IntegrityRun { ys, stats, metrics, integrity, modeled_end }
}

/// Handpicked plan with strict accounting: two MRAM flips on distinct
/// victim blocks, both due by the first integrity cycle. Every count
/// is exact because no draws can collide.
#[test]
fn keystone_mram_corruption_is_detected_repaired_delta_only_and_served_exact() {
    let (m, xs) = test_data();
    let reference = reference_ys(&xs, &m);
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let d0 = c.map().shards[0].set.dpus[5];
    let d1 = c.map().shards[1].set.dpus[60];
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::MramBitFlip { at: 1, dpu: d0, addr: GEMV_M + 17, bit: 3 },
        FaultEvent::MramBitFlip { at: 2, dpu: d1, addr: GEMV_M + 511, bit: 7 },
    ])));
    let mut sh = SelfHealingCoordinator::new(c);

    let cycle_s = sh.scrub_and_repair().unwrap();
    assert!(cycle_s > 0.0, "scrub + repair cost modeled time");

    let im = sh.integrity();
    assert_eq!(im.injected, 2);
    assert_eq!(im.detected, 2, "both flips land in scrubbed blocks: both must be caught");
    assert_eq!(im.undetected(), 0);
    assert_eq!(im.repaired, 2);
    assert_eq!(im.repaired_bytes, 2 * BLOCK_BYTES, "delta-only: exactly the two blocks moved");
    assert!(im.scrub_cycles >= 2, "a confirming re-scrub follows the repairs");
    assert!(im.scrub_s > 0.0 && im.repair_s > 0.0);
    assert!(im.mean_time_to_repair_s() > 0.0);

    // Served results are bit-identical to the corruption-free
    // reference — the repairs restored the exact resident bytes.
    let ys = serve(&mut sh, &xs);
    assert_eq!(ys, reference, "corruption must never reach a served y");

    let mut c = sh.into_inner();
    let inj = c.sys.take_chaos().unwrap();
    assert!(inj.unfired().is_empty());
    assert_eq!(inj.stats().mram_flips, 2);
    assert_eq!(inj.stats().corruptions_applied(), 2);
}

#[test]
fn keystone_seeded_corruption_replays_bit_identically() {
    let (m, xs) = test_data();
    let reference = reference_ys(&xs, &m);
    for seed in [11u64, 23, 47] {
        let a = integrity_run(seed, ExecTier::Superblock, &m, &xs);
        assert_eq!(a.ys, reference, "seed {seed}: corruption changed served results");
        assert_eq!(a.stats.corruptions_applied(), 3, "seed {seed}: all three draws applied");
        assert_eq!(a.integrity.injected, 3, "seed {seed}");
        // Two draws hitting the same block within one scrub interval
        // collapse into one mismatch, so `detected` may undershoot
        // `injected` — but never exceed it, and never reach zero (an
        // odd event count cannot fully cancel).
        assert!(
            (1..=3).contains(&a.integrity.detected),
            "seed {seed}: detected {} out of 3",
            a.integrity.detected
        );
        assert!(a.integrity.repaired >= 1, "seed {seed}");
        assert_eq!(
            a.integrity.repaired_bytes,
            BLOCK_BYTES * a.integrity.repaired,
            "seed {seed}: every repair is delta-only (one block)"
        );
        assert!(a.integrity.scrub_s > 0.0, "seed {seed}: scrub cost is modeled");
        assert_eq!(a.metrics.quarantined, vec![], "seed {seed}: corruption never quarantines");

        // Same seed → the whole run replays exactly.
        let b = integrity_run(seed, ExecTier::Superblock, &m, &xs);
        assert_eq!(a.ys, b.ys, "seed {seed}");
        assert_eq!(a.stats, b.stats, "seed {seed}: injector stats must replay exactly");
        assert_eq!(a.integrity, b.integrity, "seed {seed}: integrity ledger must replay exactly");
        assert_eq!(a.metrics, b.metrics, "seed {seed}: recovery metrics must replay exactly");
        assert_eq!(a.modeled_end, b.modeled_end, "seed {seed}: modeled clock must replay exactly");
    }
}

#[test]
fn keystone_holds_across_all_exec_tiers() {
    let (m, xs) = test_data();
    let reference = integrity_run(11, ExecTier::Stepped, &m, &xs);
    assert_eq!(reference.ys, reference_ys(&xs, &m));
    for tier in [ExecTier::Batched, ExecTier::Superblock] {
        let run = integrity_run(11, tier, &m, &xs);
        assert_eq!(run.ys, reference.ys, "{} diverged on results", tier.name());
        assert_eq!(run.stats, reference.stats, "{} diverged on the fault sequence", tier.name());
        assert_eq!(
            run.integrity,
            reference.integrity,
            "{} diverged on the integrity ledger",
            tier.name()
        );
        assert_eq!(
            run.modeled_end,
            reference.modeled_end,
            "{} diverged on the modeled clock",
            tier.name()
        );
    }
}

/// WRAM flips in the default window land in scratchpad bytes no kernel
/// ever reads: *undetectable by construction*. The contract is honest
/// accounting — the ledger must report them as injected-but-undetected,
/// and the run must not pretend the fleet was verified clean.
#[test]
fn undetectable_wram_corruption_is_reported_not_silently_passed() {
    let (m, xs) = test_data();
    let reference = reference_ys(&xs, &m);
    let run = |tier: ExecTier| {
        let mut c = sharded(tier);
        c.preload_matrix(ROWS, COLS, &m).unwrap();
        let victims: Vec<usize> =
            (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
        let cfg = ChaosConfig {
            ops: 4,
            dpu_deaths: 0,
            transient_launches: 0,
            transient_transfers: 0,
            stragglers: 0,
            wram_bit_flips: 2,
            ..ChaosConfig::default()
        };
        let plan = ChaosPlan::generate(11, &cfg, &victims);
        assert_eq!(plan.corruptions().len(), 2);
        for ev in plan.corruptions() {
            match ev {
                FaultEvent::WramBitFlip { addr, .. } => {
                    assert!((0xE000..0x1_0000).contains(&addr), "default window: dead WRAM")
                }
                other => panic!("expected only WRAM flips, got {other:?}"),
            }
        }
        c.sys.install_chaos(ChaosInjector::new(plan));
        let mut sh = SelfHealingCoordinator::new(c);
        let ys = serve(&mut sh, &xs);
        // Tick boundaries until both flips have fired, then account.
        while !sh.inner.sys.chaos().unwrap().unfired().is_empty() {
            sh.scrub_and_repair().unwrap();
        }
        (ys, sh.integrity(), sh.inner.sys.modeled_now())
    };

    let (ys, im, end) = run(ExecTier::Superblock);
    assert_eq!(ys, reference, "dead-WRAM flips cannot perturb results");
    assert_eq!(im.injected, 2, "both flips applied");
    assert_eq!(im.detected, 0, "no scrub or readback covers dead WRAM");
    assert_eq!(im.undetected(), 2, "the ledger must confess what it cannot see");
    assert_eq!(im.repaired, 0);
    assert!(im.scrub_cycles >= 1, "scrubs ran and (correctly) found nothing");
    let (ys2, im2, end2) = run(ExecTier::Superblock);
    assert_eq!((ys, im, end), (ys2, im2, end2), "the undetectable run replays exactly too");
}

/// Host-level detection layer in isolation: a transfer corruption
/// fired into a verified push is caught by the readback *of that same
/// push*, typed with full shard/block/site context, and the next
/// (clean) repush + strict scrub confirm the repair.
#[test]
fn verify_after_push_catches_in_flight_corruption() {
    let (m, _) = test_data();
    let mut c = sharded(ExecTier::Superblock);
    c.preload_matrix(ROWS, COLS, &m).unwrap();
    let victim = c.map().shards[0].set.dpus[5];
    c.sys.install_chaos(ChaosInjector::new(ChaosPlan::from_events(vec![
        FaultEvent::TransferCorruption { at: 1, dpu: victim, addr: GEMV_M + 100, bit: 2 },
    ])));

    let err = c.repush_block(0, 5).unwrap_err();
    match &err {
        Error::DataCorruption { site, shard, block } => {
            assert_eq!(*shard, 0);
            assert_eq!(*block, 5);
            assert_eq!(site.dpu, Some(victim));
            assert!(site.rank.is_some() && site.socket.is_some());
        }
        other => panic!("expected a typed DataCorruption, got {other:?}"),
    }
    assert_eq!(err.class(), ErrorClass::Permanent);
    assert!(err.to_string().contains("data corruption detected"));

    // The corrupted bytes are resident: a strict scrub agrees with the
    // readback and points at the same block.
    let scrub_err = c.scrub().unwrap_err();
    assert!(matches!(scrub_err, Error::DataCorruption { shard: 0, block: 5, .. }));

    // The plan is spent — the clean repush lands and verifies, and the
    // fleet scrubs clean.
    assert_eq!(c.repush_block(0, 5).unwrap(), BLOCK_BYTES);
    assert!(c.scrub().unwrap() > 0.0, "a clean scrub still costs modeled time");
}

fn matrix() -> Vec<i8> {
    Rng::new(7).i8_vec((ROWS * COLS) as usize)
}

/// Modeled seconds one pipelined batch costs — tier-invariant, the
/// unit arrival rates and scrub cadences below are expressed in.
fn batch_seconds(m: &[i8]) -> f64 {
    let mut c = sharded(ExecTier::Stepped);
    c.preload_matrix(ROWS, COLS, m).unwrap();
    let xs: Vec<Vec<i8>> = (0..BATCH).map(|i| vec![i as i8 + 1; COLS as usize]).collect();
    let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
    let t0 = c.sys.sync_all();
    c.gemv_pipelined(&views).unwrap();
    c.sys.sync_all() - t0
}

fn poisson_plan(seed: u64, rate_rps: f64, requests: usize, deadline_s: f64) -> TrafficPlan {
    TrafficPlan::generate(
        seed,
        &TrafficConfig {
            process: ArrivalProcess::Poisson { rate_rps },
            requests,
            deadline_s: Some(deadline_s),
            mix: WorkloadMix::single(ROWS, COLS, GemvVariant::I8Opt),
        },
    )
}

fn sim_cfg(dt: f64) -> SimConfig {
    SimConfig {
        batcher: DeadlineBatcher::new(BATCH, 0.5 * dt),
        admission: AdmissionConfig { policy: AdmissionPolicy::RejectNew, queue_cap: 16 },
        policy: Policy::LeastOutstanding,
    }
}

/// Serving integration: the open-loop sim schedules scrub cycles on
/// the modeled clock between batches; their cost and the summed
/// integrity ledger land in the report, and the whole thing replays.
#[test]
fn open_loop_scrub_cadence_accounts_integrity_and_replays() {
    let m = matrix();
    let dt = batch_seconds(&m);
    let sat = BATCH as f64 / dt;
    let plan = poisson_plan(211, 0.8 * sat, 12, 50.0 * dt);

    let run = || {
        let replicas: Vec<SelfHealingCoordinator> = (0..2u64)
            .map(|r| {
                let mut c = sharded(ExecTier::Superblock);
                c.preload_matrix(ROWS, COLS, &m).unwrap();
                let victims: Vec<usize> = (0..2)
                    .flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec())
                    .collect();
                let cfg = ChaosConfig {
                    ops: 4,
                    dpu_deaths: 0,
                    transient_launches: 0,
                    transient_transfers: 0,
                    stragglers: 0,
                    mram_bit_flips: 1,
                    corrupt_mram_len: BLOCK_BYTES as u32,
                    ..ChaosConfig::default()
                };
                c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(31 + r, &cfg, &victims)));
                SelfHealingCoordinator::new(c)
            })
            .collect();
        let mut sim = OpenLoopSim::new(sim_cfg(dt), vec![replicas]);
        sim.set_scrub_every(0.5 * dt);
        sim.run(&plan, &[])
    };

    let rep = run();
    assert_eq!(rep.served.len(), 12, "below saturation everything serves");
    assert!(rep.rejections.is_empty() && rep.failed.is_empty());
    // Each replica's one flip fired (scrub launches tick the op
    // counter even on unrouted replicas) and was caught and repaired.
    assert_eq!(rep.integrity.injected, 2);
    assert_eq!(rep.integrity.detected, 2);
    assert_eq!(rep.integrity.undetected(), 0);
    assert_eq!(rep.integrity.repaired_bytes, BLOCK_BYTES * rep.integrity.repaired);
    assert!(rep.integrity.scrub_cycles >= 2, "the cadence scrubbed both replicas repeatedly");
    assert!(rep.integrity.scrub_s > 0.0, "scrub cost is charged to the modeled timeline");

    let rep2 = run();
    assert_eq!(rep, rep2, "the scrubbed serving run must replay the whole report exactly");
}

/// A plain (non-healing) replica scrubs *strictly*: its first detected
/// mismatch surfaces as `DataCorruption`, and the sim treats that like
/// any replica failure — evict, requeue, keep serving on the survivor.
#[test]
fn strict_scrub_evicts_plain_replica_on_detection() {
    let m = matrix();
    let dt = batch_seconds(&m);
    let sat = BATCH as f64 / dt;
    let plan = poisson_plan(223, 0.8 * sat, 12, 50.0 * dt);

    let replicas: Vec<ShardedGemvCoordinator> = (0..2)
        .map(|r| {
            let mut c = sharded(ExecTier::Superblock);
            c.preload_matrix(ROWS, COLS, &m).unwrap();
            if r == 0 {
                let victims: Vec<usize> = (0..2)
                    .flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec())
                    .collect();
                let cfg = ChaosConfig {
                    ops: 2,
                    dpu_deaths: 0,
                    transient_launches: 0,
                    transient_transfers: 0,
                    stragglers: 0,
                    mram_bit_flips: 2,
                    corrupt_mram_len: BLOCK_BYTES as u32,
                    ..ChaosConfig::default()
                };
                c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(41, &cfg, &victims)));
            }
            c
        })
        .collect();
    let mut sim = OpenLoopSim::new(sim_cfg(dt), vec![replicas]);
    sim.set_scrub_every(0.25 * dt);
    let rep = sim.run(&plan, &[]);

    assert_eq!(sim.router(0).admitted(), 1, "the corrupted replica is evicted on detection");
    assert_eq!(rep.served.len(), 12, "the survivor absorbs the requeued work");
    assert!(rep.rejections.is_empty());
}
