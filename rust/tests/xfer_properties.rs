//! Property tests for the SDK-v2 transfer surface: `XferPlan` /
//! `PullPlan` round-trips, plan reuse, and one pinned timing-parity
//! test against the deprecated v1 closure shims (the only remaining v1
//! usage in the test suite, `#[allow(deprecated)]`-scoped to that
//! single function so `cargo test` stays warning-clean).

use upmem_unleashed::host::{AllocPolicy, PimSystem, PullPlan, XferPlan};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::proptest::{forall, Config};
use upmem_unleashed::util::rng::Rng;

fn system() -> PimSystem {
    PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware)
}

/// Push→pull through prepared plans returns exactly the pushed bytes,
/// for random per-DPU payload sizes and random MRAM offsets.
#[test]
fn xfer_plan_roundtrips_bytes_exactly() {
    forall(
        Config::cases(12),
        |rng| {
            let chunk = rng.range_u64(1, 2048) as usize;
            let addr = (rng.range_u64(0, 1 << 20) as u32) & !7;
            let seed = rng.next_u64();
            (chunk, addr, seed)
        },
        |&(chunk, addr, seed)| {
            let mut sys = system();
            let set = sys.alloc_ranks(2).unwrap();
            let n = set.nr_dpus();
            let mut rng = Rng::new(seed);
            let data = rng.u8_vec(n * chunk);
            let mut plan = XferPlan::to_pim(&set, addr);
            plan.prepare_chunks(&data, chunk).unwrap();
            let push = sys.push_xfer(&set, &plan).unwrap();
            let mut out = vec![0u8; n * chunk];
            let mut pull = PullPlan::from_pim(&set, addr);
            pull.prepare_chunks(&mut out, chunk).unwrap();
            let pulled = sys.pull_xfer(&set, &mut pull).unwrap();
            push.bytes == (n * chunk) as u64 && pulled.bytes == push.bytes && out == data
        },
        "XferPlan push→pull round-trips bytes exactly",
    );
}

/// The single pinned v1-parity test: the deprecated closure-based API
/// and the plan-based API must model identical traffic with identical
/// `TransferReport` timings. Everything else in the suite (and the
/// benches) runs on plans; this is the one sanctioned use of the shims.
#[test]
#[allow(deprecated)]
fn plan_timing_matches_deprecated_closure_api() {
    forall(
        Config::cases(10),
        |rng| {
            let chunk = rng.range_u64(8, 4096) as usize;
            let ranks = *rng.choose(&[2usize, 4]);
            let seed = rng.next_u64();
            (chunk, ranks, seed)
        },
        |&(chunk, ranks, seed)| {
            let mut rng = Rng::new(seed);
            let payload = rng.u8_vec(chunk);

            let mut v1 = system();
            let s1 = v1.alloc_ranks(ranks).unwrap();
            let push1 = v1.push_parallel(&s1, 4096, |_| payload.clone()).unwrap();
            let (data1, pull1) = v1.pull_parallel(&s1, 4096, chunk).unwrap();

            let mut v2 = system();
            let s2 = v2.alloc_ranks(ranks).unwrap();
            let n = s2.nr_dpus();
            let mut plan = XferPlan::to_pim(&s2, 4096);
            for i in 0..n {
                plan.prepare(i, &payload).unwrap();
            }
            let push2 = v2.push_xfer(&s2, &plan).unwrap();
            let mut out = vec![0u8; n * chunk];
            let mut pull = PullPlan::from_pim(&s2, 4096);
            pull.prepare_chunks(&mut out, chunk).unwrap();
            let pull2 = v2.pull_xfer(&s2, &mut pull).unwrap();

            push1.bytes == push2.bytes
                && (push1.seconds - push2.seconds).abs() < 1e-12
                && pull1.bytes == pull2.bytes
                && (pull1.seconds - pull2.seconds).abs() < 1e-12
                && data1.concat() == out
        },
        "plan-based and closure-based APIs model identical traffic identically",
    );
}

/// Plans are reusable: pushing the same prepared `XferPlan` twice moves
/// the same bytes with the same modeled timing, and a second pull
/// observes the final MRAM state — no hidden per-push state in the
/// zero-copy path.
#[test]
fn plans_are_reusable_across_transfers() {
    let mut sys = system();
    let set = sys.alloc_ranks(2).unwrap();
    let n = set.nr_dpus();
    let mut rng = Rng::new(0xBEEF);
    let data = rng.u8_vec(n * 256);
    let mut plan = XferPlan::to_pim(&set, 8192);
    plan.prepare_chunks(&data, 256).unwrap();
    let r1 = sys.push_xfer(&set, &plan).unwrap();
    let r2 = sys.push_xfer(&set, &plan).unwrap();
    assert_eq!(r1.bytes, r2.bytes);
    assert!((r1.seconds - r2.seconds).abs() < 1e-12);
    let mut out = vec![0u8; n * 256];
    let mut pull = PullPlan::from_pim(&set, 8192);
    pull.prepare_chunks(&mut out, 256).unwrap();
    sys.pull_xfer(&set, &mut pull).unwrap();
    assert_eq!(out, data);
}

/// Partially prepared plans move only the prepared views and report
/// only their bytes.
#[test]
fn partial_plans_move_partial_traffic() {
    let mut sys = system();
    let set = sys.alloc_ranks(2).unwrap();
    let payload = [9u8; 64];
    let mut plan = XferPlan::to_pim(&set, 0);
    plan.prepare(3, &payload).unwrap();
    plan.prepare(7, &payload).unwrap();
    let r = sys.push_xfer(&set, &plan).unwrap();
    assert_eq!(r.bytes, 128);
    let mut buf = [0u8; 64];
    sys.dpu_of(&set, 3).mram.read(0, &mut buf).unwrap();
    assert_eq!(buf, payload);
    sys.dpu_of(&set, 4).mram.read(0, &mut buf).unwrap();
    assert_eq!(buf, [0u8; 64], "unprepared DPUs must be untouched");
}

/// A plan built for one set cannot be pushed to a differently-sized set.
#[test]
fn mismatched_plan_is_rejected() {
    let mut sys = system();
    let small = sys.alloc_ranks(2).unwrap();
    let big = sys.alloc_ranks(4).unwrap();
    let plan = XferPlan::to_pim(&small, 0);
    assert!(sys.push_xfer(&big, &plan).is_err());
}
