//! Differential tests for the assembly optimizer ([`upmem_unleashed::opt`]):
//! every pass must be architecturally invisible — naive and optimized
//! builds of the same kernel produce bit-identical WRAM/MRAM contents
//! and outputs (the per-tasklet *cycle counters* at `CYCLES_BASE..AUX_BASE`
//! are the one excluded window: changing cycle counts is the optimizer's
//! entire purpose) — and, with all passes on, modeled cycles must
//! strictly improve where the paper says they do: INT32/INT8 MUL via
//! `mul_step` truncation, INT8 GEMV via cond-jump fusion + DMA
//! double-buffering.

use upmem_unleashed::dpu::Dpu;
use upmem_unleashed::kernels::arith::{
    emit_microbench_with, run_microbench_cfg, DType, MulImpl, Spec, Unroll,
};
use upmem_unleashed::kernels::bsdp::{emit_dot_microbench_with, run_dot_microbench_cfg, DotVariant};
use upmem_unleashed::kernels::gemv::{gemv_ref, run_gemv_dpu_with_cfg, GemvShape, GemvVariant};
use upmem_unleashed::kernels::{AUX_BASE, BLOCK_BYTES, CYCLES_BASE, MRAM_A};
use upmem_unleashed::opt::{optimize, PassConfig};
use upmem_unleashed::util::rng::Rng;

const BYTES: u32 = 8 * 1024;

fn naive() -> PassConfig {
    PassConfig::none()
}

fn full() -> PassConfig {
    PassConfig::all()
}

/// Compare two WRAM images, ignoring the per-tasklet cycle slots.
fn assert_wram_matches(a: &Dpu, b: &Dpu, what: &str) {
    let (wa, wb) = (a.wram.as_slice(), b.wram.as_slice());
    assert_eq!(wa.len(), wb.len());
    for (addr, (x, y)) in wa.iter().zip(wb).enumerate() {
        let addr = addr as u32;
        if (CYCLES_BASE..AUX_BASE).contains(&addr) {
            continue; // timed-region counters legitimately differ
        }
        assert_eq!(x, y, "{what}: WRAM byte {addr:#x} diverged");
    }
}

/// Every valid arith spec: naive and all-passes builds verify against
/// the host reference (so both are correct ⇒ equal), and the optimized
/// build never costs more cycles.
#[test]
fn arith_all_specs_naive_vs_optimized() {
    let specs = [
        Spec::add(DType::I8),
        Spec::add(DType::I32),
        Spec::mul(DType::I8, MulImpl::Mulsi3),
        Spec::mul(DType::I8, MulImpl::Native),
        Spec::mul(DType::I8, MulImpl::NativeX4),
        Spec::mul(DType::I8, MulImpl::NativeX8),
        Spec::mul(DType::I32, MulImpl::Mulsi3),
        Spec::mul(DType::I32, MulImpl::Dim),
    ];
    for spec in specs {
        for unroll in [Unroll::No, Unroll::X64] {
            let spec = spec.with_unroll(unroll);
            let n = run_microbench_cfg(spec, &naive(), 4, BYTES, 11)
                .unwrap_or_else(|e| panic!("{} naive: {e}", spec.name()));
            let o = run_microbench_cfg(spec, &full(), 4, BYTES, 11)
                .unwrap_or_else(|e| panic!("{} optimized: {e}", spec.name()));
            assert!(
                o.launch.cycles <= n.launch.cycles,
                "{}: optimized build slower ({} > {})",
                spec.name(),
                o.launch.cycles,
                n.launch.cycles
            );
        }
    }
}

/// The paper's §III-C headline: truncating `__mulsi3` by the scalar's
/// precision strictly improves both MUL baselines on random data.
#[test]
fn mul_step_truncation_improves_mul_cycles() {
    for (dtype, label) in [(DType::I32, "INT32 MUL"), (DType::I8, "INT8 MUL")] {
        let spec = Spec::mul(dtype, MulImpl::Mulsi3);
        let n = run_microbench_cfg(spec, &naive(), 16, BYTES, 3).unwrap();
        let o = run_microbench_cfg(spec, &full(), 16, BYTES, 3).unwrap();
        assert!(
            o.launch.cycles < n.launch.cycles,
            "{label}: all-passes {} !< naive {}",
            o.launch.cycles,
            n.launch.cycles
        );
    }
}

/// Cond-jump fusion alone buys the INT32 ADD counter latch one cycle
/// per element (`sub` + `jneq` → `sub..nz`).
#[test]
fn cond_jump_fusion_improves_int32_add() {
    let spec = Spec::add(DType::I32);
    let n = run_microbench_cfg(spec, &naive(), 16, BYTES, 5).unwrap();
    let fused = naive().set(upmem_unleashed::opt::Pass::FuseCondJumps, true);
    let o = run_microbench_cfg(spec, &fused, 16, BYTES, 5).unwrap();
    assert!(o.launch.cycles < n.launch.cycles, "{} !< {}", o.launch.cycles, n.launch.cycles);
}

/// Raw bit-identity for a data-independent arith kernel: run naive and
/// optimized programs on identically-staged DPUs and compare full
/// memory images (cycle slots masked).
#[test]
fn arith_memory_images_bit_identical() {
    for spec in [
        Spec::mul(DType::I8, MulImpl::NativeX8),
        Spec::mul(DType::I32, MulImpl::Dim),
        Spec::mul(DType::I32, MulImpl::Mulsi3),
    ] {
        let run = |cfg: &PassConfig| {
            let program = emit_microbench_with(spec, cfg).unwrap();
            let mut dpu = Dpu::new();
            dpu.load_program(&program).unwrap();
            let mut rng = Rng::new(77);
            let data: Vec<u8> = (0..BYTES).map(|_| rng.next_u32() as u8).collect();
            dpu.mram.write(MRAM_A, &data).unwrap();
            dpu.wram.store32(0, BYTES).unwrap();
            dpu.wram.store32(4, spec.scalar() as u32).unwrap();
            dpu.wram.store32(8, 4 * BLOCK_BYTES).unwrap();
            dpu.launch(4).unwrap();
            dpu
        };
        let mut a = run(&naive());
        let mut b = run(&full());
        assert_wram_matches(&a, &b, &spec.name());
        let mut ma = vec![0u8; BYTES as usize];
        let mut mb = vec![0u8; BYTES as usize];
        a.mram.read(MRAM_A, &mut ma).unwrap();
        b.mram.read(MRAM_A, &mut mb).unwrap();
        assert_eq!(ma, mb, "{}: MRAM diverged", spec.name());
    }
}

/// Dot-product kernels: correctness via the built-in reference check,
/// plus strict improvement for the unroll + shift-add passes on BSDP.
#[test]
fn dot_kernels_naive_vs_optimized() {
    for v in [
        DotVariant::NativeBaseline,
        DotVariant::NativeMulsi3,
        DotVariant::NativeOptimized,
        DotVariant::Bsdp,
    ] {
        let n = run_dot_microbench_cfg(v, &naive(), 8, 8192, 21)
            .unwrap_or_else(|e| panic!("{} naive: {e}", v.name()));
        let o = run_dot_microbench_cfg(v, &full(), 8, 8192, 21)
            .unwrap_or_else(|e| panic!("{} optimized: {e}", v.name()));
        assert_eq!(n.dot, o.dot, "{}", v.name());
        assert!(o.launch.cycles <= n.launch.cycles, "{}", v.name());
    }
    let n = run_dot_microbench_cfg(DotVariant::Bsdp, &naive(), 16, 16384, 9).unwrap();
    let o = run_dot_microbench_cfg(DotVariant::Bsdp, &full(), 16, 16384, 9).unwrap();
    assert!(
        (o.launch.cycles as f64) < 0.95 * n.launch.cycles as f64,
        "BSDP all-passes should beat naive by >5%: {} vs {}",
        o.launch.cycles,
        n.launch.cycles
    );
}

/// GEMV: every variant, naive vs all passes (including DMA
/// double-buffering at 8 tasklets), y bit-identical to the reference;
/// the optimized INT8 kernels strictly faster.
#[test]
fn gemv_naive_vs_optimized_bit_identical_and_faster() {
    let t = 8;
    for v in [
        GemvVariant::I8Baseline,
        GemvVariant::I8Mulsi3,
        GemvVariant::I8Opt,
        GemvVariant::I4Bsdp,
    ] {
        let cols = match v {
            GemvVariant::I4Bsdp => 2048,
            _ => 1024,
        };
        let shape = GemvShape { rows: 16, cols };
        let mut rng = Rng::new(31);
        let (m, x) = match v {
            GemvVariant::I4Bsdp => {
                (rng.i4_vec((shape.rows * cols) as usize), rng.i4_vec(cols as usize))
            }
            _ => (rng.i8_vec((shape.rows * cols) as usize), rng.i8_vec(cols as usize)),
        };
        let (yn, ln) = run_gemv_dpu_with_cfg(v, &naive(), shape, t, &m, &x)
            .unwrap_or_else(|e| panic!("{} naive: {e}", v.name()));
        let (yo, lo) = run_gemv_dpu_with_cfg(v, &full(), shape, t, &m, &x)
            .unwrap_or_else(|e| panic!("{} optimized: {e}", v.name()));
        let want = gemv_ref(shape, &m, &x);
        assert_eq!(yn, want, "{} naive wrong", v.name());
        assert_eq!(yo, want, "{} optimized wrong", v.name());
        assert!(
            lo.cycles < ln.cycles,
            "{}: all-passes {} !< naive {}",
            v.name(),
            lo.cycles,
            ln.cycles
        );
    }
}

/// The double-buffered layout rejects >8 tasklets instead of silently
/// colliding with the y staging region.
#[test]
fn dbuf_rejects_too_many_tasklets() {
    let shape = GemvShape { rows: 16, cols: 1024 };
    let mut rng = Rng::new(1);
    let m = rng.i8_vec((shape.rows * shape.cols) as usize);
    let x = rng.i8_vec(shape.cols as usize);
    let e = run_gemv_dpu_with_cfg(GemvVariant::I8Opt, &full(), shape, 16, &m, &x);
    assert!(e.is_err(), "16 tasklets + dbuf must be rejected");
    // Without dbuf, 16 tasklets still work under all remaining passes.
    let cfg = full().set(upmem_unleashed::opt::Pass::DmaDoubleBuffer, false);
    let (y, _) = run_gemv_dpu_with_cfg(GemvVariant::I8Opt, &cfg, shape, 16, &m, &x).unwrap();
    assert_eq!(y, gemv_ref(shape, &m, &x));
}

/// Pass statistics report the transformations the ablation tables log:
/// fused jumps, elided mul_steps, unrolled copies, removed dead code.
#[test]
fn pass_stats_report_expected_counts() {
    // INT32 __mulsi3 microbench: one annotated call (24-bit scalar).
    let spec = Spec::mul(DType::I32, MulImpl::Mulsi3);
    let p = emit_microbench_with(spec, &naive()).unwrap();
    let (_, stats) = optimize(&p, &full());
    assert_eq!(stats.mul_calls_inlined, 1);
    assert_eq!(stats.mul_steps_elided, 32 - 24);
    // The fully-inlined routine body becomes unreachable.
    assert!(stats.unreachable_removed > 0, "dead __mulsi3 body should be removed");

    // BSDP dot microbench: 8× unroll, then 10 shift-add fusions per
    // 32-element block across the 8 copies.
    let p = emit_dot_microbench_with(DotVariant::Bsdp, &naive()).unwrap();
    let (_, stats) = optimize(&p, &full());
    assert_eq!(stats.loops_unrolled, 1);
    assert_eq!(stats.loop_copies_added, 7);
    assert_eq!(stats.shift_adds_fused, 80);

    // INT32 ADD counter latch: exactly one cond-jump fusion.
    let p = emit_microbench_with(Spec::add(DType::I32), &naive()).unwrap();
    let (_, stats) = optimize(&p, &full());
    assert!(stats.cond_jumps_fused >= 1);
}

/// The differential harness itself must be deterministic: identical
/// seeds + configs reproduce identical launches, so the comparisons
/// above compare kernels, not staging noise.
#[test]
fn dot_harness_staging_is_config_independent() {
    let a = run_dot_microbench_cfg(DotVariant::NativeBaseline, &naive(), 4, 4096, 123).unwrap();
    let b = run_dot_microbench_cfg(DotVariant::NativeBaseline, &naive(), 4, 4096, 123).unwrap();
    assert_eq!(a.dot, b.dot);
    assert_eq!(a.launch, b.launch);
}
