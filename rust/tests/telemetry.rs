//! Telemetry-plane keystones: the observability artifacts are
//! *deterministic* — pure functions of (seed, topology, tier).
//!
//! Three contracts, matching `rust/src/telemetry/` docs:
//!
//! * a traced seeded traffic×chaos serving run exports **byte-identical**
//!   Chrome trace JSON on every run, and tracing never perturbs the
//!   modeled run itself (the report matches an untraced twin);
//! * the opt-in per-PC profiler is **execution-tier invariant** on the
//!   kernel matrix (arith × unroll, BSDP dot, all GEMV variants incl.
//!   the non-blocking-DMA pipeline): counts *and* post-issue-clock
//!   checksums, so the tiers agree on the exact schedule;
//! * host-level span streams (push / broadcast / launch / pull emitted
//!   by `PimSystem` + the sharded coordinator) are tier-invariant too —
//!   full event-stream equality, not just per-kind totals.

use upmem_unleashed::chaos::{ChaosConfig, ChaosInjector, ChaosPlan, SelfHealingCoordinator};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::dpu::{Dpu, ExecTier};
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::arith::{run_microbench_cfg_with, DType, MulImpl, Spec, Unroll};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench_cfg_with, DotVariant};
use upmem_unleashed::kernels::gemv::{run_gemv_dpu_cfg_on, GemvShape, GemvVariant};
use upmem_unleashed::kernels::KernelScratch;
use upmem_unleashed::opt::PassConfig;
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::telemetry::{chrome_trace_json, PcProfile, SpanKind, TraceRecorder};
use upmem_unleashed::traffic::{
    AdmissionConfig, AdmissionPolicy, ArrivalProcess, DeadlineBatcher, OpenLoopSim, SimConfig,
    TrafficConfig, TrafficPlan, TrafficReport, WorkloadMix,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

const FAST_TIERS: [ExecTier; 2] = [ExecTier::Batched, ExecTier::Superblock];

const ROWS: u32 = 128;
const COLS: u32 = 512;
const BATCH: usize = 4;
const REPLICAS: usize = 2;
const CHAOS_SEED: u64 = 47;

fn sharded(tier: ExecTier, m: &[i8]) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    sys.set_exec_tier(tier);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).expect("2 shards x 1 rank");
    let map = ShardMap::new(sets, NumaBalanced.name()).expect("shard map");
    let mut c = ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8);
    c.preload_matrix(ROWS, COLS, m).expect("preload");
    c
}

/// The open-loop bench's chaos-mid-burst scenario at test size: two
/// self-healing replicas with seeded device-fault plans plus one
/// plan-scheduled replica loss, tight deadlines, 1.5× a nominal rate.
fn chaos_serving_run(tier: ExecTier, traced: bool) -> (Option<TraceRecorder>, TrafficReport) {
    let m = Rng::new(4242).i8_vec((ROWS * COLS) as usize);
    let requests = 12usize;
    let loss_cfg = ChaosConfig {
        ops: requests as u64,
        dpu_deaths: 0,
        transient_launches: 0,
        transient_transfers: 0,
        stragglers: 0,
        replica_losses: 1,
        replicas: REPLICAS,
        ..ChaosConfig::default()
    };
    let losses = ChaosPlan::generate(CHAOS_SEED, &loss_cfg, &[]).replica_losses();
    let replicas: Vec<SelfHealingCoordinator> = (0..REPLICAS as u64)
        .map(|r| {
            let mut c = sharded(tier, &m);
            let victims: Vec<usize> =
                (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
            let ccfg = ChaosConfig { ops: 6, ..ChaosConfig::default() };
            c.sys.install_chaos(ChaosInjector::new(ChaosPlan::generate(
                CHAOS_SEED + r,
                &ccfg,
                &victims,
            )));
            SelfHealingCoordinator::new(c)
        })
        .collect();
    // A fixed nominal batch time keeps the plan identical per tier and
    // per run without a calibration pass.
    let dt = 0.002f64;
    let p = TrafficPlan::generate(
        CHAOS_SEED,
        &TrafficConfig {
            process: ArrivalProcess::Poisson { rate_rps: 1.5 * REPLICAS as f64 * BATCH as f64 / dt },
            requests,
            deadline_s: Some(8.0 * dt),
            mix: WorkloadMix::single(ROWS, COLS, GemvVariant::I8Opt),
        },
    );
    let cfg = SimConfig {
        batcher: DeadlineBatcher::new(BATCH, 0.5 * dt),
        admission: AdmissionConfig { policy: AdmissionPolicy::RejectNew, queue_cap: 2 * BATCH },
        policy: Policy::SloAware,
    };
    let mut sim = OpenLoopSim::new(cfg, vec![replicas]);
    if traced {
        sim.install_trace(TraceRecorder::new());
    }
    let rep = sim.run(&p, &losses);
    (sim.take_trace(), rep)
}

#[test]
fn traced_chaos_serving_exports_byte_identically_and_never_perturbs() {
    let (tr1, rep1) = chaos_serving_run(ExecTier::Superblock, true);
    let (tr2, rep2) = chaos_serving_run(ExecTier::Superblock, true);
    let (none, untraced) = chaos_serving_run(ExecTier::Superblock, false);
    assert!(none.is_none(), "no recorder installed, none to take");
    assert_eq!(rep1, untraced, "tracing must never perturb the modeled run");
    assert_eq!(rep1, rep2, "seeded run replays exactly");
    let tr1 = tr1.expect("trace recorded");
    let tr2 = tr2.expect("trace recorded");
    assert!(!tr1.is_empty(), "the chaos scenario emits serving spans");
    let json1 = chrome_trace_json(tr1.events());
    let json2 = chrome_trace_json(tr2.events());
    assert_eq!(json1, json2, "double-run Chrome trace JSON is byte-identical");
    // The scenario exercises the serving-level kinds end to end.
    let kinds: Vec<SpanKind> = tr1.totals().iter().map(|&(k, _, _)| k).collect();
    assert!(kinds.contains(&SpanKind::BatchClose), "kinds seen: {kinds:?}");
}

#[test]
fn serving_span_totals_are_tier_invariant() {
    let (tr_ref, rep_ref) = chaos_serving_run(ExecTier::Stepped, true);
    let tr_ref = tr_ref.expect("trace recorded");
    for tier in FAST_TIERS {
        let (tr, rep) = chaos_serving_run(tier, true);
        let tr = tr.expect("trace recorded");
        assert_eq!(rep_ref, rep, "report diverged on {}", tier.name());
        assert_eq!(tr_ref.totals(), tr.totals(), "span totals diverged on {}", tier.name());
        assert_eq!(tr_ref, tr, "event stream diverged on {}", tier.name());
    }
}

/// Run one single-DPU kernel with the profiler on; return its profile.
fn profiled<F>(tier: ExecTier, run: F) -> PcProfile
where
    F: FnOnce(&mut KernelScratch),
{
    let mut scr = KernelScratch::default();
    scr.dpu.set_exec_tier(tier);
    scr.dpu.set_profile_enabled(true);
    run(&mut scr);
    scr.dpu.take_profile().expect("profiler was enabled")
}

#[test]
fn per_pc_profiles_are_tier_invariant_on_the_kernel_matrix() {
    type Case = (&'static str, Box<dyn Fn(&mut KernelScratch)>);
    let cases: Vec<Case> = vec![
        (
            "arith add i8 x64",
            Box::new(|scr| {
                let spec = Spec::add(DType::I8).with_unroll(Unroll::X64);
                run_microbench_cfg_with(scr, spec, &spec.default_passes(), 16, 8 * 1024, 99)
                    .map(|_| ())
                    .expect("verified arith run");
            }),
        ),
        (
            "arith mul i8 native-x4",
            Box::new(|scr| {
                let spec = Spec::mul(DType::I8, MulImpl::NativeX4);
                run_microbench_cfg_with(scr, spec, &spec.default_passes(), 16, 8 * 1024, 99)
                    .map(|_| ())
                    .expect("verified arith run");
            }),
        ),
        (
            "bsdp dot",
            Box::new(|scr| {
                run_dot_microbench_cfg_with(scr, DotVariant::Bsdp, &PassConfig::all(), 16, 8 * 2048, 7)
                    .map(|_| ())
                    .expect("verified dot run");
            }),
        ),
    ];
    for (name, run) in &cases {
        let reference = profiled(ExecTier::Stepped, run);
        assert!(!reference.is_empty(), "{name}: profiler saw issues");
        for tier in FAST_TIERS {
            let got = profiled(tier, run);
            assert_eq!(
                reference,
                got,
                "{name}: per-PC profile (counts + cycle sums) diverged on {}",
                tier.name()
            );
        }
    }
}

#[test]
fn gemv_profiles_are_tier_invariant_including_nonblocking_dma() {
    let rows = 16u32;
    let mut rng = Rng::new(4242);
    let m8 = rng.i8_vec((rows * 1024) as usize);
    let x8 = rng.i8_vec(1024);
    let m4 = rng.i4_vec((rows * 2048) as usize);
    let x4 = rng.i4_vec(2048);
    let cases: Vec<(GemvVariant, PassConfig, usize)> = vec![
        (GemvVariant::I8Baseline, GemvVariant::I8Baseline.default_passes(), 16),
        (GemvVariant::I8Opt, GemvVariant::I8Opt.default_passes(), 16),
        (GemvVariant::I4Bsdp, GemvVariant::I4Bsdp.default_passes(), 16),
        // `ldma_nb`/`dma_wait` inside superblock windows: the profiler's
        // arithmetic cycle attribution must still match stepped exactly.
        (GemvVariant::I8Opt, PassConfig::all(), 8),
    ];
    for (variant, cfg, tasklets) in &cases {
        let (shape, m, x) = if *variant == GemvVariant::I4Bsdp {
            (GemvShape { rows, cols: 2048 }, &m4, &x4)
        } else {
            (GemvShape { rows, cols: 1024 }, &m8, &x8)
        };
        let run = |tier: ExecTier| -> PcProfile {
            let mut dpu = Dpu::new();
            dpu.set_exec_tier(tier);
            dpu.set_profile_enabled(true);
            run_gemv_dpu_cfg_on(&mut dpu, *variant, cfg, shape, *tasklets, m, x)
                .expect("gemv run");
            dpu.take_profile().expect("profiler was enabled")
        };
        let reference = run(ExecTier::Stepped);
        assert!(!reference.is_empty());
        for tier in FAST_TIERS {
            assert_eq!(
                reference,
                run(tier),
                "{} ({tasklets}T) profile diverged on {}",
                variant.name(),
                tier.name()
            );
        }
    }
}

/// Host-level span streams (scatter + push + broadcast + launch + pull
/// emitted under the sharded coordinator) and the fleet-merged per-PC
/// profile, per tier, on one pipelined batch.
#[test]
fn host_span_stream_and_fleet_profile_are_tier_invariant() {
    let m = Rng::new(4242).i8_vec((ROWS * COLS) as usize);
    let run = |tier: ExecTier| -> (TraceRecorder, PcProfile, Vec<Vec<i32>>) {
        let mut c = sharded(tier, &m);
        c.sys.install_trace(TraceRecorder::new());
        let nshards = c.map().shards.len();
        for s in 0..nshards {
            let set = c.map().shards[s].set.clone();
            c.sys.set_profile_enabled(&set, true);
        }
        let xs: Vec<Vec<i8>> = (0..BATCH).map(|i| vec![i as i8 + 1; COLS as usize]).collect();
        let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
        let (ys, _) = c.gemv_pipelined(&views).expect("pipelined batch");
        let tr = c.sys.take_trace().expect("recorder installed");
        let mut profile = PcProfile::new();
        for s in 0..nshards {
            let set = c.map().shards[s].set.clone();
            profile.merge(&c.sys.collect_profile(&set));
        }
        (tr, profile, ys)
    };
    let (tr_ref, prof_ref, y_ref) = run(ExecTier::Stepped);
    assert!(!tr_ref.is_empty(), "the traced batch emits host spans");
    let kinds: Vec<SpanKind> = tr_ref.totals().iter().map(|&(k, _, _)| k).collect();
    for want in [SpanKind::Launch, SpanKind::Pull] {
        assert!(kinds.contains(&want), "missing {want:?} in {kinds:?}");
    }
    assert!(!prof_ref.is_empty(), "fleet profile saw issues");
    for tier in FAST_TIERS {
        let (tr, prof, ys) = run(tier);
        assert_eq!(y_ref, ys, "gemv outputs diverged on {}", tier.name());
        assert_eq!(tr_ref, tr, "host span stream diverged on {}", tier.name());
        assert_eq!(prof_ref, prof, "fleet profile diverged on {}", tier.name());
    }
}
