//! Property contract for the in-PIM scrub kernel
//! (`rust/src/kernels/scrub.rs`): the checksum a simulated DPU
//! publishes equals the host-side golden checksum — over random block
//! shapes (zero-length, singleton, non-power-of-two, chunk-boundary
//! ±1), every interpreter execution tier, the pass extremes *and*
//! random optimizer pass subsets — and every injected single-bit flip
//! changes it.
//!
//! Fleet-level scrubbing (golden table, coordinator diff, repair) is
//! pinned by `integrity_recovery.rs`; this file isolates the kernel.

use upmem_unleashed::dpu::ExecTier;
use upmem_unleashed::kernels::scrub::{golden_block_checksum, run_scrub_dpu};
use upmem_unleashed::kernels::KernelScratch;
use upmem_unleashed::opt::{PassConfig, ALL_PASSES};
use upmem_unleashed::util::rng::Rng;

/// Block sizes in bytes. The scrub chunk is 256 i32 words = 1024 B, so
/// the sweep crosses the chunk boundary, the word boundary and the
/// 512 B block size the integrity keystone serves at.
const SHAPES: [usize; 12] = [0, 1, 3, 4, 511, 512, 1020, 1023, 1024, 1025, 2048, 4096];
const TASKLETS: [usize; 3] = [1, 5, 16];

fn subset(mask: u8) -> PassConfig {
    let mut cfg = PassConfig::none();
    for (bit, pass) in ALL_PASSES.into_iter().enumerate() {
        if mask & (1u8 << bit) != 0 {
            cfg = cfg.set(pass, true);
        }
    }
    cfg
}

#[test]
fn scrub_matches_host_golden_across_shapes_tiers_and_pass_subsets() {
    let mut rng = Rng::new(0x91);
    let tiers = [ExecTier::Stepped, ExecTier::Batched, ExecTier::Superblock];
    let mut scrs: Vec<KernelScratch> = tiers
        .iter()
        .map(|&tier| {
            let mut scr = KernelScratch::default();
            scr.dpu.set_exec_tier(tier);
            scr
        })
        .collect();
    for n in SHAPES {
        let data = rng.u8_vec(n);
        let want = golden_block_checksum(&data);
        for t in TASKLETS {
            // The extremes plus a seeded random pass subset: the scrub
            // checksum is an architectural value, so no optimizer
            // configuration may perturb it.
            let random_cfg = subset(rng.next_u64() as u8);
            for cfg in [PassConfig::none(), PassConfig::all(), random_cfg] {
                for (scr, tier) in scrs.iter_mut().zip(tiers) {
                    let got = run_scrub_dpu(scr, &cfg, t, &data)
                        .unwrap_or_else(|e| panic!("scrub n={n} t={t} {}: {e}", tier.name()));
                    assert_eq!(got, want, "n={n} t={t} tier {}", tier.name());
                }
            }
        }
    }
}

/// The detection guarantee, exercised end-to-end on the DPU: flip one
/// random bit of a random block and the published checksum must move
/// (a wrapping word sum changes by ±2^k mod 2^32, never zero) — and
/// must equal the host golden of the rotten block, so the coordinator
/// diff localizes it.
#[test]
fn scrub_detects_every_injected_single_bit_flip() {
    let mut rng = Rng::new(0x92);
    let mut scr = KernelScratch::default();
    for round in 0..32 {
        let n = 1 + rng.below(2048) as usize;
        let data = rng.u8_vec(n);
        let clean = golden_block_checksum(&data);
        assert_eq!(
            run_scrub_dpu(&mut scr, &PassConfig::all(), 8, &data).unwrap(),
            clean,
            "round {round}: clean block n={n}"
        );
        let mut rotten = data.clone();
        let byte = rng.below(n as u64) as usize;
        let bit = rng.below(8) as u8;
        rotten[byte] ^= 1 << bit;
        let got = run_scrub_dpu(&mut scr, &PassConfig::all(), 8, &rotten).unwrap();
        assert_ne!(got, clean, "round {round}: flip at byte {byte} bit {bit} went unseen");
        assert_eq!(got, golden_block_checksum(&rotten), "round {round}: host/DPU disagree");
    }
}
