//! Determinism contract of the parallel fleet executor: running a fleet
//! launch on 1, 2 or 8 worker threads must produce **bit-identical**
//! outcomes — per-DPU `LaunchResult`s (`cycles`, `instrs`, DMA bytes),
//! the fleet's modeled `seconds`/`max_cycles`, every DPU's WRAM and
//! MRAM state, and, on the fault path, the *same* `Error::Fault`
//! (first faulting DPU in set order, regardless of thread
//! interleaving).

use upmem_unleashed::dpu::assemble;
use upmem_unleashed::host::{AllocPolicy, DpuSet, PimSystem};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::Error;

/// A kernel whose work varies per DPU (via a host-written WRAM arg) and
/// per tasklet (via `id`), with DMA traffic and a barrier — enough
/// texture that any merge-order or scheduling bug shows up in cycles,
/// WRAM or MRAM.
const VARYING_SRC: &str = "move r9, 0\n\
                           lw r9, r9, 4\n\
                           move r0, id\n\
                           add r0, r0, r9\n\
                           loop:\n\
                           sub r0, r0, 1\n\
                           jneq r0, 0, @loop\n\
                           move r1, id4\n\
                           add r1, r1, 256\n\
                           add r2, r9, id\n\
                           sw r1, 0, r2\n\
                           barrier\n\
                           move r3, 256\n\
                           move r4, 8192\n\
                           sdma r3, r4, 64\n\
                           stop\n";

/// Faults (explicit `fault` instruction) iff the host wrote 1 to
/// WRAM[8]; all other DPUs run a short loop and stop.
const FAULTING_SRC: &str = "move r0, 0\n\
                            lw r0, r0, 8\n\
                            jeq r0, 1, @bad\n\
                            move r1, 5\n\
                            spin:\n\
                            sub r1, r1, 1\n\
                            jneq r1, 0, @spin\n\
                            stop\n\
                            bad:\n\
                            fault\n";

fn fleet(workers: usize) -> (PimSystem, DpuSet) {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    sys.set_launch_workers(workers);
    let set = sys.alloc_ranks(2).unwrap(); // 128 DPUs across 2 ranks
    (sys, set)
}

/// Everything a launch can influence, snapshotted for comparison.
#[derive(PartialEq, Debug)]
struct Snapshot {
    per_dpu: Vec<upmem_unleashed::dpu::LaunchResult>,
    seconds: f64,
    max_cycles: u64,
    /// (wram window, mram window) for a sample of DPUs across chunks.
    state: Vec<(Vec<u8>, Vec<u8>)>,
    modeled_now: f64,
}

fn run_varying(workers: usize, tasklets: usize) -> Snapshot {
    let (mut sys, set) = fleet(workers);
    let prog = assemble(VARYING_SRC).unwrap();
    sys.load_program(&set, &prog).unwrap();
    for i in 0..set.nr_dpus() {
        // Loop counts differ per DPU, non-monotonically, so the slowest
        // DPU sits mid-fleet (exercises the max_cycles merge).
        let count = 3 + ((i as u32 * 37) % 101);
        sys.dpu_of(&set, i).wram.store32(4, count).unwrap();
    }
    let fleet = sys.launch(&set, tasklets).unwrap();
    let mut state = Vec::new();
    for i in [0usize, 1, 17, 63, 64, 100, 127] {
        let dpu = sys.dpu_of(&set, i);
        let wram = dpu.wram.as_slice()[256..512].to_vec();
        let mut mram = vec![0u8; 64];
        dpu.mram.read(8192, &mut mram).unwrap();
        state.push((wram, mram));
    }
    Snapshot {
        per_dpu: fleet.per_dpu.clone(),
        seconds: fleet.seconds,
        max_cycles: fleet.max_cycles,
        state,
        modeled_now: sys.modeled_now(),
    }
}

#[test]
fn parallel_launch_is_bit_identical_to_serial() {
    for tasklets in [1, 8] {
        let serial = run_varying(1, tasklets);
        assert_eq!(serial.per_dpu.len(), 128);
        // Work differs across DPUs, so a wrong merge order cannot hide.
        assert!(
            serial.per_dpu.iter().any(|r| r.cycles != serial.per_dpu[0].cycles),
            "test kernel must produce non-uniform per-DPU cycles"
        );
        for workers in [2, 8] {
            let parallel = run_varying(workers, tasklets);
            assert_eq!(
                serial, parallel,
                "{workers}-worker launch diverged from serial ({tasklets} tasklets)"
            );
        }
    }
}

fn run_faulting(workers: usize, fault_at: &[usize]) -> Error {
    let (mut sys, set) = fleet(workers);
    let prog = assemble(FAULTING_SRC).unwrap();
    sys.load_program(&set, &prog).unwrap();
    for &i in fault_at {
        sys.dpu_of(&set, i).wram.store32(8, 1).unwrap();
    }
    sys.launch(&set, 4).unwrap_err()
}

#[test]
fn mid_fleet_fault_is_stable_across_worker_counts() {
    // Two faulting DPUs in different worker chunks: the reported fault
    // must always be the first one in *set order* (index 37), never a
    // thread-race winner.
    let (sys_probe, set_probe) = fleet(1);
    let expected_dpu = set_probe.dpus[37];
    drop(sys_probe);
    let serial = run_faulting(1, &[90, 37]);
    match &serial {
        Error::Fault { dpu, kind, .. } => {
            assert_eq!(*dpu, expected_dpu, "serial fault must be set-order-first");
            assert_eq!(*kind, upmem_unleashed::FaultKind::Explicit);
        }
        other => panic!("expected a Fault, got {other}"),
    }
    for workers in [2, 8] {
        let parallel = run_faulting(workers, &[90, 37]);
        assert_eq!(serial, parallel, "fault diverged at {workers} workers");
    }
}

#[test]
fn fleet_state_after_fault_matches_serial() {
    // The fleet keeps running past a fault (hardware semantics); the
    // surviving DPUs' results must match the serial path bit-for-bit.
    let run = |workers: usize| {
        let (mut sys, set) = fleet(workers);
        let prog = assemble(FAULTING_SRC).unwrap();
        sys.load_program(&set, &prog).unwrap();
        sys.dpu_of(&set, 37).wram.store32(8, 1).unwrap();
        let err = sys.launch(&set, 4).unwrap_err();
        let mut survivors = Vec::new();
        for i in [0usize, 36, 38, 127] {
            survivors.push(sys.dpu_of(&set, i).wram.as_slice()[0..64].to_vec());
        }
        (err, survivors)
    };
    let serial = run(1);
    for workers in [2, 8] {
        assert_eq!(serial, run(workers));
    }
}
