//! Differential contract for the PrIM workload suite built on the
//! kernel framework (`rust/src/framework/`): reduction, histogram,
//! inclusive scan and select/stream-compaction.
//!
//! Every runner verifies its output element-by-element against the
//! matching `cpu_ref::prim` host reference before returning, so a
//! plain `.unwrap()` here is already a differential check. This file
//! sweeps the shape space (zero-length, singleton, non-power-of-two,
//! chunk-boundary ±1), the tasklet counts, both pass extremes, all
//! three interpreter execution tiers, non-default histogram bin
//! counts, and the fleet entry points through `PimSystem`.
//!
//! Strict tier snapshots (LaunchResult + WRAM image equality) live in
//! `tier_differential.rs`; random pass subsets as a property live in
//! `kernel_properties.rs`.

use upmem_unleashed::dpu::ExecTier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::{histogram, reduce, scan, select, KernelScratch};
use upmem_unleashed::opt::PassConfig;
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

/// Chunk boundary for the i32 kernels is 256 elements; for the u8
/// histogram it is 1024. The sweep crosses both.
const SHAPES: [usize; 9] = [0, 1, 7, 255, 256, 257, 1000, 1023, 1025];
const TASKLETS: [usize; 3] = [1, 3, 16];

#[test]
fn reduce_differential_sweep() {
    let mut rng = Rng::new(0x51);
    let mut scr = KernelScratch::default();
    for n in SHAPES {
        let data = rng.i32_vec(n);
        for t in TASKLETS {
            for cfg in [PassConfig::none(), PassConfig::all()] {
                let out = reduce::run_reduce_cfg_with(&mut scr, &cfg, t, &data)
                    .unwrap_or_else(|e| panic!("reduce n={n} t={t}: {e}"));
                assert_eq!(out.sum, upmem_unleashed::cpu_ref::prim::reduce_i32(&data));
            }
        }
    }
}

#[test]
fn histogram_differential_sweep() {
    let mut rng = Rng::new(0x52);
    let mut scr = KernelScratch::default();
    for n in SHAPES {
        let data = rng.u8_vec(n);
        for t in TASKLETS {
            for cfg in [PassConfig::none(), PassConfig::all()] {
                let out = histogram::run_histogram_cfg_with(&mut scr, &cfg, t, 256, &data)
                    .unwrap_or_else(|e| panic!("histogram n={n} t={t}: {e}"));
                assert_eq!(out.hist, upmem_unleashed::cpu_ref::prim::histogram_u8(&data, 256));
            }
        }
    }
}

#[test]
fn histogram_non_default_bins() {
    let mut rng = Rng::new(0x53);
    let mut scr = KernelScratch::default();
    let data = rng.u8_vec(3000);
    for bins in [2u32, 8, 32, 128] {
        for t in [1usize, 5, 16] {
            let out =
                histogram::run_histogram_cfg_with(&mut scr, &PassConfig::all(), t, bins, &data)
                    .unwrap_or_else(|e| panic!("histogram bins={bins} t={t}: {e}"));
            assert_eq!(out.hist, upmem_unleashed::cpu_ref::prim::histogram_u8(&data, bins));
        }
    }
}

#[test]
fn scan_differential_sweep() {
    let mut rng = Rng::new(0x54);
    let mut scr = KernelScratch::default();
    for n in SHAPES {
        let data = rng.i32_vec(n);
        for t in TASKLETS {
            for cfg in [PassConfig::none(), PassConfig::all()] {
                scan::run_scan_cfg_with(&mut scr, &cfg, t, &data)
                    .unwrap_or_else(|e| panic!("scan n={n} t={t}: {e}"));
            }
        }
    }
}

#[test]
fn select_differential_sweep() {
    let mut rng = Rng::new(0x55);
    let mut scr = KernelScratch::default();
    for n in SHAPES {
        let data = rng.i32_vec(n);
        for t in TASKLETS {
            for cfg in [PassConfig::none(), PassConfig::all()] {
                select::run_select_cfg_with(&mut scr, &cfg, t, &data)
                    .unwrap_or_else(|e| panic!("select n={n} t={t}: {e}"));
            }
        }
    }
}

/// Every PrIM kernel verifies against the host reference on all three
/// interpreter tiers (the strict snapshot comparison is in
/// `tier_differential.rs`; this asserts the *contract* per tier).
#[test]
fn all_kernels_verify_on_every_tier() {
    let mut rng = Rng::new(0x56);
    let i32s = rng.i32_vec(1500);
    let bytes = rng.u8_vec(5000);
    for tier in [ExecTier::Stepped, ExecTier::Batched, ExecTier::Superblock] {
        let mut scr = KernelScratch::default();
        scr.dpu.set_exec_tier(tier);
        let cfg = PassConfig::all();
        reduce::run_reduce_cfg_with(&mut scr, &cfg, 16, &i32s)
            .unwrap_or_else(|e| panic!("reduce on {}: {e}", tier.name()));
        histogram::run_histogram_cfg_with(&mut scr, &cfg, 16, 256, &bytes)
            .unwrap_or_else(|e| panic!("histogram on {}: {e}", tier.name()));
        scan::run_scan_cfg_with(&mut scr, &cfg, 16, &i32s)
            .unwrap_or_else(|e| panic!("scan on {}: {e}", tier.name()));
        select::run_select_cfg_with(&mut scr, &cfg, 16, &i32s)
            .unwrap_or_else(|e| panic!("select on {}: {e}", tier.name()));
    }
}

/// Fleet entry points: the same four kernels through `PimSystem` on a
/// full rank (64 DPUs), with host-side cross-DPU combination. The
/// fleet runners verify against `cpu_ref::prim` internally.
#[test]
fn fleet_entry_points_verify() {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(1).unwrap();
    let mut rng = Rng::new(0x57);
    let cfg = PassConfig::all();

    // Enough data that many (not all) DPUs own chunks — the empty-DPU
    // path is part of the contract.
    let i32s = rng.i32_vec(40_000);
    let bytes = rng.u8_vec(90_000);

    let sum = reduce::run_reduce_fleet(&mut sys, &set, &cfg, 12, &i32s).unwrap();
    assert_eq!(sum, upmem_unleashed::cpu_ref::prim::reduce_i32(&i32s));

    let hist = histogram::run_histogram_fleet(&mut sys, &set, &cfg, 12, 256, &bytes).unwrap();
    assert_eq!(hist, upmem_unleashed::cpu_ref::prim::histogram_u8(&bytes, 256));

    let scanned = scan::run_scan_fleet(&mut sys, &set, &cfg, 12, &i32s).unwrap();
    assert_eq!(scanned, upmem_unleashed::cpu_ref::prim::scan_i32(&i32s));

    let kept = select::run_select_fleet(&mut sys, &set, &cfg, 12, &i32s).unwrap();
    assert_eq!(kept, upmem_unleashed::cpu_ref::prim::select_pos(&i32s));
}

/// Degenerate fleet shapes: empty input and fewer chunks than DPUs.
#[test]
fn fleet_handles_degenerate_shapes() {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    let set = sys.alloc_ranks(1).unwrap();
    let cfg = PassConfig::all();
    assert_eq!(reduce::run_reduce_fleet(&mut sys, &set, &cfg, 4, &[]).unwrap(), 0);
    let tiny: Vec<i32> = vec![5, -3, 9];
    assert_eq!(reduce::run_reduce_fleet(&mut sys, &set, &cfg, 4, &tiny).unwrap(), 11);
    assert_eq!(scan::run_scan_fleet(&mut sys, &set, &cfg, 4, &tiny).unwrap(), vec![5, 2, 11]);
    assert_eq!(select::run_select_fleet(&mut sys, &set, &cfg, 4, &tiny).unwrap(), vec![5, 9]);
    assert_eq!(
        histogram::run_histogram_fleet(&mut sys, &set, &cfg, 4, 2, &[0x10, 0x90]).unwrap(),
        vec![1, 1]
    );
}
