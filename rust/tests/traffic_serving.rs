//! Traffic-plane contracts (ISSUE 8 acceptance pins):
//!
//! 1. **Keystone**: a seeded (traffic plan × chaos plan) open-loop run
//!    reproduces the served / shed / deadline-violated id sets, the
//!    latency percentiles and every replica's [`RecoveryMetrics`]
//!    bit-identically on a double run and across all three
//!    [`ExecTier`]s.
//! 2. Below saturation with no chaos the pool sheds nothing and every
//!    served `y` is bit-identical to the unbatched [`gemv_ref`]
//!    reference.
//! 3. At 2× saturation the pool sheds with typed
//!    [`Error::Overloaded`], never queues past the admission cap, and
//!    keeps goodput at or above what a single saturated replica could
//!    deliver while at least one replica stays admitted.
//!
//! All rates are derived from a one-batch calibration on the modeled
//! clock (which is tier-invariant — chaos_recovery.rs pins that), so
//! the same plan drives every tier.

use upmem_unleashed::chaos::{
    ChaosConfig, ChaosInjector, ChaosPlan, RecoveryMetrics, SelfHealingCoordinator,
};
use upmem_unleashed::coordinator::router::Policy;
use upmem_unleashed::dpu::ExecTier;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::{gemv_ref, GemvShape, GemvVariant};
use upmem_unleashed::plane::{NumaBalanced, PlacementPolicy, ShardMap, ShardedGemvCoordinator};
use upmem_unleashed::traffic::{
    gen_x, AdmissionConfig, AdmissionPolicy, ArrivalProcess, DeadlineBatcher, OpenLoopSim,
    SimConfig, TrafficConfig, TrafficPlan, TrafficReport, WorkloadMix,
};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;
use upmem_unleashed::Error;

const ROWS: u32 = 128;
const COLS: u32 = 512;
const BATCH: usize = 4;

fn sharded(tier: ExecTier) -> ShardedGemvCoordinator {
    let mut sys = PimSystem::new(SystemTopology::pristine(), AllocPolicy::NumaAware);
    sys.set_exec_tier(tier);
    let sets = sys.alloc_shards(&NumaBalanced, 2, 1).unwrap();
    let map = ShardMap::new(sets, NumaBalanced.name()).unwrap();
    ShardedGemvCoordinator::new(sys, map, GemvVariant::I8Opt, 8)
}

fn matrix() -> Vec<i8> {
    Rng::new(7).i8_vec((ROWS * COLS) as usize)
}

/// Modeled seconds one full pipelined batch takes on a pristine
/// replica — the unit every arrival rate in this file is expressed in.
/// Tier-invariant (the modeled clock is), so one calibration serves
/// all tiers.
fn batch_seconds(m: &[i8]) -> f64 {
    let mut c = sharded(ExecTier::Stepped);
    c.preload_matrix(ROWS, COLS, m).unwrap();
    let xs: Vec<Vec<i8>> = (0..BATCH).map(|i| vec![i as i8 + 1; COLS as usize]).collect();
    let views: Vec<&[i8]> = xs.iter().map(|v| v.as_slice()).collect();
    let t0 = c.sys.sync_all();
    c.gemv_pipelined(&views).unwrap();
    let dt = c.sys.sync_all() - t0;
    assert!(dt > 0.0, "calibration batch must cost modeled time");
    dt
}

fn poisson_plan(seed: u64, rate_rps: f64, requests: usize, deadline_s: Option<f64>) -> TrafficPlan {
    TrafficPlan::generate(
        seed,
        &TrafficConfig {
            process: ArrivalProcess::Poisson { rate_rps },
            requests,
            deadline_s,
            mix: WorkloadMix::single(ROWS, COLS, GemvVariant::I8Opt),
        },
    )
}

fn sim_cfg(policy: AdmissionPolicy, cap: usize, window_s: f64, routing: Policy) -> SimConfig {
    SimConfig {
        batcher: DeadlineBatcher::new(BATCH, window_s),
        admission: AdmissionConfig { policy, queue_cap: cap },
        policy: routing,
    }
}

/// One keystone run: two self-healing replicas (each under its own
/// seeded device-chaos plan, victims drawn mid-shard so coverage
/// survives), driven by `plan` with a chaos-scheduled replica loss.
fn traffic_chaos_run(
    tier: ExecTier,
    m: &[i8],
    plan: &TrafficPlan,
    losses: &[(u64, usize)],
    cfg: &SimConfig,
) -> (TrafficReport, Vec<RecoveryMetrics>) {
    let replicas: Vec<SelfHealingCoordinator> = (0..2u64)
        .map(|r| {
            let mut c = sharded(tier);
            c.preload_matrix(ROWS, COLS, m).unwrap();
            let victims: Vec<usize> =
                (0..2).flat_map(|s| c.map().shards[s].set.dpus[32..40].to_vec()).collect();
            let ccfg = ChaosConfig { ops: 6, ..ChaosConfig::default() };
            let plan = ChaosPlan::generate(31 + r, &ccfg, &victims);
            c.sys.install_chaos(ChaosInjector::new(plan));
            SelfHealingCoordinator::new(c)
        })
        .collect();
    let mut sim = OpenLoopSim::new(cfg.clone(), vec![replicas]);
    let rep = sim.run(plan, losses);
    let metrics = (0..2).map(|r| sim.backend(0, r).metrics().clone()).collect();
    (rep, metrics)
}

#[test]
fn keystone_traffic_times_chaos_replays_bit_identically_across_tiers() {
    let m = matrix();
    let dt = batch_seconds(&m);
    let sat = BATCH as f64 / dt; // one replica's saturation req/s

    // 1.5× the two-replica pool capacity, tight-ish deadlines, and a
    // chaos-plan-scheduled replica loss mid-stream: overload, deadline
    // pressure, device faults and replica failover all in one run.
    let requests = 24usize;
    let plan = poisson_plan(101, 3.0 * sat, requests, Some(6.0 * dt));
    let loss_cfg = ChaosConfig {
        ops: requests as u64,
        dpu_deaths: 0,
        transient_launches: 0,
        transient_transfers: 0,
        stragglers: 0,
        replica_losses: 1,
        replicas: 2,
        ..ChaosConfig::default()
    };
    let losses = ChaosPlan::generate(101, &loss_cfg, &[]).replica_losses();
    assert_eq!(losses.len(), 1, "the committed seed schedules one replica loss");
    let cfg = sim_cfg(AdmissionPolicy::RejectNew, 6, 0.5 * dt, Policy::SloAware);

    let (rep_a, rm_a) = traffic_chaos_run(ExecTier::Stepped, &m, &plan, &losses, &cfg);
    assert!(!rep_a.served.is_empty(), "overloaded ≠ dead: admitted traffic serves");
    assert_eq!(rep_a.metrics.requests, requests as u64);
    assert_eq!(
        rep_a.served.len() + rep_a.rejections.len(),
        requests,
        "every request is served or typed-shed, none lost silently"
    );
    assert!(rep_a.max_queue_depth <= 6, "bounded queues under chaos + overload");
    // Device chaos fired and healed on at least one replica.
    assert!(rm_a.iter().any(|mx| mx.retries > 0), "chaos plans cost retries");

    // Double run: the full report (id sets, ys, percentiles, modeled
    // end) and every replica's recovery metrics replay bit-exactly.
    let (rep_b, rm_b) = traffic_chaos_run(ExecTier::Stepped, &m, &plan, &losses, &cfg);
    assert_eq!(rep_a, rep_b, "double run must replay the whole report exactly");
    assert_eq!(rep_a.latency_summary(), rep_b.latency_summary());
    assert_eq!(rm_a, rm_b, "recovery metrics must replay exactly");

    // And across every execution tier.
    for tier in [ExecTier::Batched, ExecTier::Superblock] {
        let (rep_t, rm_t) = traffic_chaos_run(tier, &m, &plan, &losses, &cfg);
        assert_eq!(rep_a, rep_t, "{} diverged on the traffic report", tier.name());
        assert_eq!(rm_a, rm_t, "{} diverged on recovery metrics", tier.name());
    }
}

#[test]
fn below_saturation_no_chaos_serves_exact_and_sheds_nothing() {
    let m = matrix();
    let dt = batch_seconds(&m);
    let sat = BATCH as f64 / dt;

    // One replica's saturation rate split across two replicas (50%
    // pool utilization), 12 requests against a 16-deep cap: overload
    // is impossible by construction and deadlines are generous.
    let requests = 12usize;
    let plan = poisson_plan(103, sat, requests, Some(50.0 * dt));
    let cfg = sim_cfg(AdmissionPolicy::RejectNew, 16, 0.5 * dt, Policy::LeastOutstanding);
    let replicas: Vec<ShardedGemvCoordinator> = (0..2)
        .map(|_| {
            let mut c = sharded(ExecTier::Superblock);
            c.preload_matrix(ROWS, COLS, &m).unwrap();
            c
        })
        .collect();
    let mut sim = OpenLoopSim::new(cfg, vec![replicas]);
    let rep = sim.run(&plan, &[]);

    assert_eq!(rep.served.len(), requests);
    assert!(rep.rejections.is_empty(), "no sheds below saturation");
    assert!(rep.deadline_violations.is_empty());
    assert!(rep.failed.is_empty());
    assert_eq!(rep.metrics.shed_rate(), 0.0);
    assert_eq!(rep.goodput(), 1.0);
    // Every served y is bit-identical to the unbatched reference on
    // the payload re-derived from the plan seed alone.
    let shape = GemvShape { rows: ROWS, cols: COLS };
    for (id, y) in &rep.ys {
        let x = gen_x(GemvVariant::I8Opt, COLS, plan.requests()[*id as usize].xseed);
        assert_eq!(y, &gemv_ref(shape, &m, &x), "request {id} diverged from gemv_ref");
    }
}

#[test]
fn two_x_saturation_sheds_typed_and_keeps_single_replica_goodput() {
    let m = matrix();
    let dt = batch_seconds(&m);
    let sat = BATCH as f64 / dt;

    // 2× the two-replica pool capacity: sheds are inevitable (excess
    // arrivals overflow the 2×4 queue slots), but both replicas stay
    // admitted and the pool must keep at least one saturated replica's
    // worth of throughput.
    let requests = 40usize;
    let plan = poisson_plan(107, 4.0 * sat, requests, None);
    let cfg = sim_cfg(AdmissionPolicy::RejectNew, BATCH, 0.25 * dt, Policy::LeastOutstanding);
    let replicas: Vec<ShardedGemvCoordinator> = (0..2)
        .map(|_| {
            let mut c = sharded(ExecTier::Superblock);
            c.preload_matrix(ROWS, COLS, &m).unwrap();
            c
        })
        .collect();
    let mut sim = OpenLoopSim::new(cfg, vec![replicas]);
    let rep = sim.run(&plan, &[]);

    assert!(rep.metrics.shed_overload > 0, "2× saturation must shed");
    assert!(rep.max_queue_depth <= BATCH, "bounded queue invariant holds under overload");
    for (_, e) in &rep.rejections {
        match e {
            Error::Overloaded { queue_depth, .. } => {
                assert!(*queue_depth <= BATCH, "shed response reports a bounded depth")
            }
            other => panic!("only typed overload sheds expected, got {other:?}"),
        }
    }
    assert!(rep.rejections.iter().all(|(_, e)| e.is_transient()), "overload sheds are retryable");
    assert_eq!(sim.router(0).admitted(), 2, "no replica was lost to overload");
    // Goodput floor: with ≥1 replica admitted, the overloaded pool
    // still moves at least ~a single saturated replica's rate (0.75
    // slack covers the startup window and the final drain tail).
    assert!(
        rep.throughput_rps() >= 0.75 * sat,
        "throughput {:.1} req/s under 2× load fell below a single replica's {:.1} req/s",
        rep.throughput_rps(),
        sat
    );
    assert_eq!(rep.served.len() + rep.rejections.len(), requests);
}
