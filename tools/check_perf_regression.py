#!/usr/bin/env python3
"""CI perf-regression gate over BENCH_perf.json (schema v2).

Compares the per-workload *modeled cycles* of a fresh bench run against
the committed baseline and fails on regressions beyond the threshold.
Modeled cycles are deterministic (unlike host Minstr/s), so the gate is
stable on shared CI runners — but only when both files were produced at
the same workload sizes (CI runs both under PERF_SMOKE=1). Since the
tiered execution engine, modeled cycles are also execution-tier
invariant, so CI gates each tier's run against one shared baseline —
a tier whose cycle model drifts fails here even before the Rust
differential tests run.

Usage:
    check_perf_regression.py BASELINE.json FRESH.json [--threshold 0.10]
    check_perf_regression.py BASELINE.json FRESH.json --arm-bootstrap

Failure modes (exit 1) — the gate *fails*, never silently skips:
  * the fresh run is not schema v2 or carries no modeled_cycles rows;
  * a workload present in the baseline is missing from the fresh run
    (renamed or dropped bench cases must update the baseline in the
    same change, otherwise their protection silently disarms);
  * any workload regressed more than the threshold;
  * the baseline is still a bootstrap placeholder and --arm-bootstrap
    was not given.

--arm-bootstrap: if (and only if) the baseline is a bootstrap
placeholder (or missing/empty), write a normalized baseline — workload
names + modeled_cycles only, host-dependent throughput dropped — to the
baseline path from the fresh run, print it, and exit 0. CI runs this
on a *scratch copy* of the committed placeholder, after (and
independently of) the gate: the gate itself always compares against
the committed file — failing loudly while it is still a placeholder —
and the printed armed baseline is what a maintainer commits to turn
the gate green and permanent. CI additionally cross-checks the
stepped/batched tier runs against the same job's superblock JSON
(tier-invariant modeled cycles, near-zero threshold), which needs no
committed baseline at all. Once the committed baseline is armed the
flag is a no-op.
"""

import argparse
import json
import sys


def workloads(doc):
    out = {}
    for name, rec in (doc.get("workloads") or {}).items():
        if isinstance(rec, dict) and "modeled_cycles" in rec:
            out[name] = rec["modeled_cycles"]
    return out


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}


def is_bootstrap(doc):
    return bool(doc.get("bootstrap")) or not workloads(doc)


def arm_baseline(path, fresh_doc):
    armed = {
        "schema_version": 2,
        "note": ("Armed from a fresh PERF_SMOKE run (tools/check_perf_regression.py "
                 "--arm-bootstrap). Workload names + modeled_cycles only: cycles are "
                 "deterministic and tier/worker/machine-invariant; host Minstr/s is "
                 "intentionally dropped. Refresh by re-running --arm-bootstrap on a "
                 "bootstrap placeholder, or by editing alongside any bench rename."),
        "meta": fresh_doc.get("meta", {}),
        "workloads": {
            name: {"modeled_cycles": cycles}
            for name, cycles in workloads(fresh_doc).items()
        },
    }
    with open(path, "w") as f:
        json.dump(armed, f, indent=2)
        f.write("\n")
    return armed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional cycle regression (default 10%%)")
    ap.add_argument("--exact", action="store_true",
                    help="fail on divergence in EITHER direction beyond the "
                         "threshold (cycle *improvements* included) — the "
                         "cross-tier consistency mode, where modeled cycles "
                         "must be invariant, not merely non-regressing")
    ap.add_argument("--arm-bootstrap", action="store_true",
                    help="if the baseline is a bootstrap placeholder, replace it "
                         "with the fresh run's modeled cycles and exit 0")
    args = ap.parse_args()

    # Only the baseline may legitimately be absent (bootstrap case);
    # a missing fresh report is an operator error worth naming.
    try:
        with open(args.fresh) as f:
            fresh_doc = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: fresh report {args.fresh} does not exist — run the "
              "perf_simulator bench first (or fix the path)")
        return 1
    fresh = workloads(fresh_doc)
    if fresh_doc.get("schema_version") != 2:
        print(f"FAIL: {args.fresh} is not schema_version 2")
        return 1
    if not fresh:
        print(f"FAIL: {args.fresh} carries no modeled_cycles workloads")
        return 1

    base_doc = load(args.baseline)
    if args.arm_bootstrap:
        if is_bootstrap(base_doc):
            armed = arm_baseline(args.baseline, fresh_doc)
            print(f"ARMED: {args.baseline} written from {args.fresh} "
                  f"({len(armed['workloads'])} gated workloads). Commit it to make "
                  "the gate permanent:")
            print(json.dumps(armed, indent=2))
        else:
            print(f"OK: {args.baseline} is already armed "
                  f"({len(workloads(base_doc))} gated workloads); nothing to do.")
        return 0

    base = workloads(base_doc)
    if is_bootstrap(base_doc):
        print(f"FAIL: baseline {args.baseline} is a bootstrap placeholder — the gate "
              "is disarmed. Run a full PERF_SMOKE bench and arm it:\n"
              f"  python3 tools/check_perf_regression.py {args.baseline} {args.fresh} "
              "--arm-bootstrap\nthen commit the baseline. Fresh values were:")
        print(json.dumps(fresh_doc, indent=2))
        return 1

    regressions, improvements, missing = [], [], []
    for name, want in sorted(base.items()):
        got = fresh.get(name)
        if got is None:
            missing.append(name)
            continue
        rel = (got - want) / want if want else 0.0
        marker = "ok"
        if rel > args.threshold:
            regressions.append((name, want, got, rel))
            marker = "REGRESSION"
        elif rel < -args.threshold:
            if args.exact:
                # Invariance mode: a tier modeling *fewer* cycles than
                # the reference is just as broken as one modeling more.
                regressions.append((name, want, got, rel))
                marker = "DIVERGENCE"
            else:
                improvements.append((name, want, got, rel))
                marker = "improved"
        print(f"  {marker:>10}  {name}: {want} -> {got} ({rel:+.1%})")

    for name in fresh:
        if name not in base:
            print(f"  {'new':>10}  {name}: {fresh[name]} (not in baseline)")
    for name in missing:
        print(f"  {'missing':>10}  {name}: in baseline but not in fresh run")

    if improvements:
        print(f"NOTE: {len(improvements)} workload(s) improved past the threshold — "
              f"refresh {args.baseline} to lock in the gains.")
    if missing:
        print(f"FAIL: {len(missing)} gated workload(s) vanished from the fresh run — "
              f"renamed or dropped bench cases must update {args.baseline} in the "
              "same change, otherwise their regression protection silently disarms.")
    if regressions:
        verb = "diverged" if args.exact else "regressed"
        print(f"FAIL: {len(regressions)} workload(s) {verb} more than "
              f"{args.threshold:.0%} in modeled cycles.")
    if regressions or missing:
        return 1
    print("PASS: no modeled-cycle regression beyond "
          f"{args.threshold:.0%} across {len(base)} gated workload(s).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
