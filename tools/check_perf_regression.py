#!/usr/bin/env python3
"""CI perf-regression gate over schema-v2 bench reports.

Compares the deterministic per-workload metrics of a fresh bench run
against the committed baseline and fails on regressions beyond the
threshold. Two metric kinds are gated, with opposite directions:

  * ``modeled_cycles`` — modeled DPU cycles (perf_simulator rows):
    deterministic, *higher is worse*;
  * ``rate`` — modeled GB/s or req/s (fig11_transfer placement rows,
    the sharded-serving rows): deterministic, *lower is worse*.

Host Minstr/s is never gated (machine-dependent). Both files must be
produced at the same workload sizes (CI runs both under PERF_SMOKE=1
where applicable). Since the tiered execution engine, modeled cycles
and modeled rates are also execution-tier invariant, so CI gates each
tier's run against one shared baseline — a tier whose model drifts
fails here even before the Rust differential tests run.

Usage:
    check_perf_regression.py BASELINE.json FRESH.json [--threshold 0.10]
    check_perf_regression.py BASELINE.json FRESH.json --arm-bootstrap

Failure modes (exit 1) — the gate *fails*, never silently skips:
  * the fresh run is not schema v2 or carries no gated metrics;
  * a workload metric present in the baseline is missing from the fresh
    run (renamed or dropped bench cases must update the baseline in the
    same change, otherwise their protection silently disarms);
  * any workload metric regressed more than the threshold;
  * the baseline is still a bootstrap placeholder and --arm-bootstrap
    was not given.

--arm-bootstrap: if (and only if) the baseline is a bootstrap
placeholder (or missing/empty), write a normalized baseline — workload
names + gated metrics only, host-dependent throughput dropped — to the
baseline path from the fresh run, print it, and exit 0. CI runs this
on a *scratch copy* of the committed placeholder, after (and
independently of) the gate: the gate itself always compares against
the committed file — failing loudly while it is still a placeholder —
and the printed armed baseline is what a maintainer commits to turn
the gate green and permanent. CI additionally cross-checks the
stepped/batched tier runs against the same job's superblock JSON
(tier-invariant metrics, near-zero threshold), which needs no
committed baseline at all. Once the committed baseline is armed the
flag is a no-op.
"""

import argparse
import json
import sys

# Gated metrics and their regression direction: +1 = higher is worse
# (costs), -1 = lower is worse (rates).
METRICS = {
    "modeled_cycles": 1,
    "rate": -1,
}


def workloads(doc):
    """name -> {metric: value} for every gated metric a row carries."""
    out = {}
    for name, rec in (doc.get("workloads") or {}).items():
        if not isinstance(rec, dict):
            continue
        metrics = {k: rec[k] for k in METRICS if k in rec}
        if metrics:
            out[name] = metrics
    return out


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}


def is_bootstrap(doc):
    return bool(doc.get("bootstrap")) or not workloads(doc)


def arm_baseline(path, fresh_doc):
    armed = {
        "schema_version": 2,
        "note": ("Armed from a fresh run (tools/check_perf_regression.py "
                 "--arm-bootstrap). Workload names + gated metrics only "
                 "(modeled_cycles, rate): both are deterministic and "
                 "tier/worker/machine-invariant; host Minstr/s is "
                 "intentionally dropped. Refresh by re-running --arm-bootstrap "
                 "on a bootstrap placeholder, or by editing alongside any "
                 "bench rename."),
        "meta": fresh_doc.get("meta", {}),
        "workloads": {
            name: dict(metrics)
            for name, metrics in workloads(fresh_doc).items()
        },
    }
    with open(path, "w") as f:
        json.dump(armed, f, indent=2)
        f.write("\n")
    return armed


def compare(base, fresh, threshold, exact):
    """Diff two workloads() maps.

    Returns (regressions, improvements, missing, lines): the first three
    are lists of human-readable row identifiers, `lines` the full
    per-metric report. A regression is drift in the metric's *worse*
    direction beyond `threshold`; with `exact`, improvements beyond the
    threshold are regressions too (invariance mode).
    """
    regressions, improvements, missing, lines = [], [], [], []
    for name, base_metrics in sorted(base.items()):
        fresh_metrics = fresh.get(name)
        for metric, want in sorted(base_metrics.items()):
            label = f"{name} [{metric}]"
            got = None if fresh_metrics is None else fresh_metrics.get(metric)
            if got is None:
                missing.append(label)
                lines.append(f"  {'missing':>10}  {label}: in baseline but not in fresh run")
                continue
            rel = (got - want) / want if want else 0.0
            worse = METRICS[metric] * rel  # positive == worse
            marker = "ok"
            if worse > threshold:
                regressions.append(label)
                marker = "REGRESSION"
            elif worse < -threshold:
                if exact:
                    # Invariance mode: drift in EITHER direction is broken.
                    regressions.append(label)
                    marker = "DIVERGENCE"
                else:
                    improvements.append(label)
                    marker = "improved"
            lines.append(f"  {marker:>10}  {label}: {want} -> {got} ({rel:+.1%})")
    for name, fresh_metrics in sorted(fresh.items()):
        for metric in sorted(fresh_metrics):
            if name not in base or metric not in base[name]:
                lines.append(
                    f"  {'new':>10}  {name} [{metric}]: {fresh_metrics[metric]} "
                    "(not in baseline)")
    return regressions, improvements, missing, lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional regression (default 10%%)")
    ap.add_argument("--exact", action="store_true",
                    help="fail on divergence in EITHER direction beyond the "
                         "threshold (improvements included) — the cross-tier "
                         "consistency mode, where the deterministic metrics "
                         "must be invariant, not merely non-regressing")
    ap.add_argument("--arm-bootstrap", action="store_true",
                    help="if the baseline is a bootstrap placeholder, replace it "
                         "with the fresh run's gated metrics and exit 0")
    args = ap.parse_args()

    # Only the baseline may legitimately be absent (bootstrap case);
    # a missing fresh report is an operator error worth naming.
    try:
        with open(args.fresh) as f:
            fresh_doc = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: fresh report {args.fresh} does not exist — run the "
              "producing bench first (or fix the path)")
        return 1
    fresh = workloads(fresh_doc)
    if fresh_doc.get("schema_version") != 2:
        print(f"FAIL: {args.fresh} is not schema_version 2")
        return 1
    if not fresh:
        print(f"FAIL: {args.fresh} carries no gated workload metrics")
        return 1

    base_doc = load(args.baseline)
    if args.arm_bootstrap:
        if is_bootstrap(base_doc):
            armed = arm_baseline(args.baseline, fresh_doc)
            print(f"ARMED: {args.baseline} written from {args.fresh} "
                  f"({len(armed['workloads'])} gated workloads). Commit it to make "
                  "the gate permanent:")
            print(json.dumps(armed, indent=2))
        else:
            print(f"OK: {args.baseline} is already armed "
                  f"({len(workloads(base_doc))} gated workloads); nothing to do.")
        return 0

    base = workloads(base_doc)
    if is_bootstrap(base_doc):
        print(f"FAIL: baseline {args.baseline} is a bootstrap placeholder — the gate "
              "is disarmed. Run the producing bench and arm it:\n"
              f"  python3 tools/check_perf_regression.py {args.baseline} {args.fresh} "
              "--arm-bootstrap\nthen commit the baseline. Fresh values were:")
        print(json.dumps(fresh_doc, indent=2))
        return 1

    regressions, improvements, missing, lines = compare(
        base, fresh, args.threshold, args.exact)
    for line in lines:
        print(line)

    if improvements:
        print(f"NOTE: {len(improvements)} workload metric(s) improved past the "
              f"threshold — refresh {args.baseline} to lock in the gains.")
    if missing:
        print(f"FAIL: {len(missing)} gated workload metric(s) vanished from the fresh "
              f"run — renamed or dropped bench cases must update {args.baseline} in "
              "the same change, otherwise their regression protection silently "
              "disarms.")
    if regressions:
        verb = "diverged" if args.exact else "regressed"
        print(f"FAIL: {len(regressions)} workload metric(s) {verb} more than "
              f"{args.threshold:.0%}.")
    if regressions or missing:
        return 1
    n_metrics = sum(len(m) for m in base.values())
    print("PASS: no regression beyond "
          f"{args.threshold:.0%} across {n_metrics} gated metric(s) "
          f"on {len(base)} workload(s).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
