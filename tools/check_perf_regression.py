#!/usr/bin/env python3
"""CI perf-regression gate over BENCH_perf.json (schema v2).

Compares the per-workload *modeled cycles* of a fresh bench run against
the committed baseline and fails on regressions beyond the threshold.
Modeled cycles are deterministic (unlike host Minstr/s), so the gate is
stable on shared CI runners — but only when both files were produced at
the same workload sizes (CI runs both under PERF_SMOKE=1).

Usage:
    check_perf_regression.py BASELINE.json FRESH.json [--threshold 0.10]

Bootstrap: a baseline with "bootstrap": true (or no "workloads" map)
passes with a notice printing the fresh values, so the first toolchain
run can commit them.
"""

import argparse
import json
import sys


def workloads(doc):
    out = {}
    for name, rec in (doc.get("workloads") or {}).items():
        if isinstance(rec, dict) and "modeled_cycles" in rec:
            out[name] = rec["modeled_cycles"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="allowed fractional cycle regression (default 10%%)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh_doc = json.load(f)
    fresh = workloads(fresh_doc)
    if fresh_doc.get("schema_version") != 2:
        print(f"FAIL: {args.fresh} is not schema_version 2")
        return 1
    if not fresh:
        print(f"FAIL: {args.fresh} carries no modeled_cycles workloads")
        return 1

    try:
        with open(args.baseline) as f:
            base_doc = json.load(f)
    except FileNotFoundError:
        base_doc = {}
    base = workloads(base_doc)
    if base_doc.get("bootstrap") or not base:
        print(f"NOTICE: baseline {args.baseline} is a bootstrap placeholder — "
              "no gate applied. Commit the fresh values to arm it:")
        print(json.dumps(fresh_doc, indent=2))
        return 0

    regressions, improvements, missing = [], [], []
    for name, want in sorted(base.items()):
        got = fresh.get(name)
        if got is None:
            missing.append(name)
            continue
        rel = (got - want) / want if want else 0.0
        marker = "ok"
        if rel > args.threshold:
            regressions.append((name, want, got, rel))
            marker = "REGRESSION"
        elif rel < -args.threshold:
            improvements.append((name, want, got, rel))
            marker = "improved"
        print(f"  {marker:>10}  {name}: {want} -> {got} ({rel:+.1%})")

    for name in fresh:
        if name not in base:
            print(f"  {'new':>10}  {name}: {fresh[name]} (not in baseline)")
    for name in missing:
        print(f"  {'missing':>10}  {name}: in baseline but not in fresh run")

    if improvements:
        print(f"NOTE: {len(improvements)} workload(s) improved past the threshold — "
              f"refresh {args.baseline} to lock in the gains.")
    if missing:
        print(f"FAIL: {len(missing)} gated workload(s) vanished from the fresh run — "
              f"renamed or dropped bench cases must update {args.baseline} in the "
              "same change, otherwise their regression protection silently disarms.")
    if regressions:
        print(f"FAIL: {len(regressions)} workload(s) regressed more than "
              f"{args.threshold:.0%} in modeled cycles.")
    if regressions or missing:
        return 1
    print("PASS: no modeled-cycle regression beyond "
          f"{args.threshold:.0%} across {len(base)} gated workload(s).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
