#!/usr/bin/env python3
"""CI tooling for Chrome trace-event JSON emitted by the telemetry plane.

The Rust side (``telemetry::chrome_trace_json``) writes complete
("ph":"X") events on the modeled clock — one JSON object per line inside
a plain array, timestamps in microseconds. Because the traces are pure
functions of (seed, topology, tier), CI can do three things with them:

  validate TRACE.json            — schema check: every event is a complete
                                   event with a name, numeric non-negative
                                   ts/dur, and pid/tid fields;
  summarize TRACE.json           — per-span-kind count + total modeled
                                   duration (µs), name-sorted;
  diff A.json B.json [--exact]   — compare two traces' per-kind summaries;
                                   with --exact, also require the event
                                   streams to be identical event-by-event
                                   (the cross-tier invariance gate).

Exit codes: 0 = pass, 1 = validation failure / diff mismatch / bad input.
Accepts either a bare event array or a ``{"traceEvents": [...]}`` wrapper
(both are valid chrome://tracing / Perfetto inputs).
"""

import argparse
import json
import sys


def load_events(path):
    """Read a trace file and return its event list.

    Raises ValueError on anything that is not a bare array or a
    ``{"traceEvents": [...]}`` object.
    """
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict):
        doc = doc.get("traceEvents")
    if not isinstance(doc, list):
        raise ValueError(f"{path}: not a trace-event array "
                         "(expected a JSON array or {'traceEvents': [...]})")
    return doc


def validate_events(events, path="trace"):
    """Return a list of human-readable schema problems (empty = valid)."""
    problems = []
    for i, ev in enumerate(events):
        where = f"{path}[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: event is not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            problems.append(f"{where}: missing or empty 'name'")
        if ev.get("ph") != "X":
            problems.append(f"{where}: ph={ev.get('ph')!r} (only complete "
                            "'X' events are emitted)")
        for field in ("ts", "dur"):
            v = ev.get(field)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"{where}: '{field}' is not numeric")
            elif v < 0:
                problems.append(f"{where}: '{field}' is negative ({v})")
        for field in ("pid", "tid"):
            if field not in ev:
                problems.append(f"{where}: missing '{field}'")
    return problems


def summarize_events(events):
    """name -> (count, total_dur_us), insertion-independent (sorted)."""
    out = {}
    for ev in events:
        name = ev.get("name", "?")
        count, total = out.get(name, (0, 0.0))
        out[name] = (count + 1, total + float(ev.get("dur", 0.0)))
    return dict(sorted(out.items()))


def summary_lines(summary):
    lines = [f"  {'kind':<12} {'count':>7} {'total µs':>14}"]
    for name, (count, total) in summary.items():
        lines.append(f"  {name:<12} {count:>7} {total:>14.6f}")
    return lines


def diff_summaries(a, b, tol=1e-9):
    """Human-readable mismatches between two summarize_events() maps."""
    problems = []
    for name in sorted(set(a) | set(b)):
        ca, ta = a.get(name, (0, 0.0))
        cb, tb = b.get(name, (0, 0.0))
        if ca != cb:
            problems.append(f"kind {name}: count {ca} != {cb}")
        elif abs(ta - tb) > tol:
            problems.append(f"kind {name}: total dur {ta:.6f} != {tb:.6f} µs")
    return problems


def cmd_validate(args):
    events = load_events(args.trace)
    problems = validate_events(events, args.trace)
    for p in problems:
        print(f"  INVALID  {p}")
    if problems:
        print(f"FAIL: {args.trace}: {len(problems)} schema problem(s) "
              f"across {len(events)} event(s).")
        return 1
    print(f"PASS: {args.trace}: {len(events)} valid complete event(s).")
    return 0


def cmd_summarize(args):
    events = load_events(args.trace)
    summary = summarize_events(events)
    print(f"{args.trace}: {len(events)} event(s), {len(summary)} kind(s)")
    for line in summary_lines(summary):
        print(line)
    return 0


def cmd_diff(args):
    a = load_events(args.a)
    b = load_events(args.b)
    problems = diff_summaries(summarize_events(a), summarize_events(b))
    if args.exact and not problems and a != b:
        # Same per-kind totals but different streams — locate the first
        # diverging event so the CI log points at it.
        n = min(len(a), len(b))
        idx = next((i for i in range(n) if a[i] != b[i]), n)
        problems.append(f"event streams differ at index {idx} "
                        f"({len(a)} vs {len(b)} events)")
    for p in problems:
        print(f"  MISMATCH  {p}")
    if problems:
        mode = "exactly " if args.exact else ""
        print(f"FAIL: {args.a} and {args.b} do not {mode}match "
              f"({len(problems)} mismatch(es)).")
        return 1
    mode = "identical event streams" if args.exact else "matching per-kind summaries"
    print(f"PASS: {args.a} vs {args.b}: {mode} "
          f"({len(a)} event(s)).")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("validate", help="schema-check one trace file")
    p.add_argument("trace")
    p.set_defaults(fn=cmd_validate)

    p = sub.add_parser("summarize", help="per-kind count + total duration")
    p.add_argument("trace")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("diff", help="compare two traces' per-kind summaries")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--exact", action="store_true",
                   help="also require byte-level event-stream identity — the "
                        "cross-tier invariance mode (modeled traces must be "
                        "identical across execution tiers, not merely similar)")
    p.set_defaults(fn=cmd_diff)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (ValueError, FileNotFoundError, json.JSONDecodeError) as e:
        print(f"FAIL: {e}")
        return 1


if __name__ == "__main__":
    sys.exit(main())
