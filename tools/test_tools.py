"""Self-checks for the CI tooling (run: python3 -m unittest discover -s tools).

These pin the behaviours the Rust-side gates depend on — regression
direction per metric, bootstrap handling, arming, and the EXPERIMENTS.md
section filler — against synthetic fixtures, so a tooling regression
fails CI before it can mask a perf regression.
"""

import json
import os
import tempfile
import unittest

import check_perf_regression as cpr
import fill_experiments as fe
import merge_bench_json as mbj
import trace_tools as tt


def doc(workloads, schema=2, **extra):
    d = {"schema_version": schema, "workloads": workloads}
    d.update(extra)
    return d


class WorkloadExtraction(unittest.TestCase):
    def test_extracts_both_metrics_and_skips_ungated_rows(self):
        w = cpr.workloads(doc({
            "a": {"minstr_per_s": 12.0, "modeled_cycles": 100},
            "b": {"minstr_per_s": 3.0},                      # host-only: not gated
            "c": {"rate": 21.5},
            "d": {"modeled_cycles": 7, "rate": 2.0},
        }))
        self.assertEqual(w, {
            "a": {"modeled_cycles": 100},
            "c": {"rate": 21.5},
            "d": {"modeled_cycles": 7, "rate": 2.0},
        })

    def test_bootstrap_detection(self):
        self.assertTrue(cpr.is_bootstrap({"bootstrap": True, "workloads": {}}))
        self.assertTrue(cpr.is_bootstrap(doc({})))
        self.assertTrue(cpr.is_bootstrap(doc({"a": {"minstr_per_s": 1.0}})))
        self.assertFalse(cpr.is_bootstrap(doc({"a": {"modeled_cycles": 5}})))
        self.assertFalse(cpr.is_bootstrap(doc({"a": {"rate": 5.0}})))


class Compare(unittest.TestCase):
    def run_compare(self, base, fresh, threshold=0.10, exact=False):
        return cpr.compare(cpr.workloads(doc(base)), cpr.workloads(doc(fresh)),
                           threshold, exact)

    def test_cycle_increase_is_a_regression(self):
        reg, imp, miss, _ = self.run_compare(
            {"w": {"modeled_cycles": 100}}, {"w": {"modeled_cycles": 120}})
        self.assertEqual(reg, ["w [modeled_cycles]"])
        self.assertEqual((imp, miss), ([], []))

    def test_cycle_decrease_is_an_improvement(self):
        reg, imp, _, _ = self.run_compare(
            {"w": {"modeled_cycles": 100}}, {"w": {"modeled_cycles": 80}})
        self.assertEqual(reg, [])
        self.assertEqual(imp, ["w [modeled_cycles]"])

    def test_rate_direction_is_inverted(self):
        # A rate DROP is the regression; a rate gain is the improvement.
        reg, imp, _, _ = self.run_compare(
            {"w": {"rate": 20.0}}, {"w": {"rate": 15.0}})
        self.assertEqual(reg, ["w [rate]"])
        reg, imp, _, _ = self.run_compare(
            {"w": {"rate": 20.0}}, {"w": {"rate": 25.0}})
        self.assertEqual(reg, [])
        self.assertEqual(imp, ["w [rate]"])

    def test_within_threshold_is_ok(self):
        reg, imp, miss, _ = self.run_compare(
            {"w": {"modeled_cycles": 100, "rate": 10.0}},
            {"w": {"modeled_cycles": 105, "rate": 9.6}})
        self.assertEqual((reg, imp, miss), ([], [], []))

    def test_exact_mode_fails_improvements_too(self):
        reg, imp, _, _ = self.run_compare(
            {"w": {"modeled_cycles": 100}}, {"w": {"modeled_cycles": 50}},
            threshold=0.0001, exact=True)
        self.assertEqual(reg, ["w [modeled_cycles]"])
        self.assertEqual(imp, [])

    def test_missing_metric_and_missing_workload_are_flagged(self):
        reg, imp, miss, _ = self.run_compare(
            {"w": {"modeled_cycles": 100, "rate": 5.0}, "gone": {"rate": 1.0}},
            {"w": {"modeled_cycles": 100}})
        self.assertEqual(reg, [])
        self.assertEqual(sorted(miss), ["gone [rate]", "w [rate]"])

    def test_new_rows_are_reported_not_failed(self):
        reg, imp, miss, lines = self.run_compare(
            {"w": {"modeled_cycles": 100}},
            {"w": {"modeled_cycles": 100}, "extra": {"rate": 3.0}})
        self.assertEqual((reg, imp, miss), ([], [], []))
        self.assertTrue(any("new" in l and "extra" in l for l in lines))


class ArmBaseline(unittest.TestCase):
    def test_arming_keeps_both_gated_metrics_drops_minstr(self):
        fresh = doc({
            "cyc": {"minstr_per_s": 9.0, "modeled_cycles": 42, "tier": "stepped"},
            "rate": {"minstr_per_s": 0.0, "rate": 21.5},
        }, meta={"smoke": True})
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "baseline.json")
            armed = cpr.arm_baseline(path, fresh)
            with open(path) as f:
                on_disk = json.load(f)
        self.assertEqual(armed, on_disk)
        self.assertEqual(on_disk["workloads"], {
            "cyc": {"modeled_cycles": 42},
            "rate": {"rate": 21.5},
        })
        self.assertEqual(on_disk["meta"], {"smoke": True})
        self.assertFalse(cpr.is_bootstrap(on_disk))


class FillExperiments(unittest.TestCase):
    PERF = doc({
        "INT8 ADD": {"minstr_per_s": 12.345, "modeled_cycles": 999},
        "aggregate": {"minstr_per_s": 5.0},
        "sharded GEMV modeled req/s [placement=linear]":
            {"minstr_per_s": 0.0, "rate": 123.456},
    })
    TRANSFER = doc({
        "plane scatter 4x2 numa-balanced (GB/s)": {"minstr_per_s": 0.0, "rate": 21.987},
    })

    def test_fills_minstr_cycles_and_req_s(self):
        lines = [
            "| workload | Minstr/s | modeled cycles |",
            "|---|---|---|",
            "| INT8 ADD | _pending_ | _pending_ |",
            "| aggregate | _pending_ | _pending_ |",
            "| unknown row | _pending_ | _pending_ |",
            "",
            "| workload | req/s |",
            "|---|---|",
            "| sharded GEMV modeled req/s [placement=linear] | _pending_ |",
        ]
        n = fe.fill_perf(lines, self.PERF)
        self.assertEqual(n, 3)
        self.assertEqual(lines[2], "| INT8 ADD | 12.3 | 999 |")
        self.assertEqual(lines[3], "| aggregate | 5.0 | — |")
        self.assertIn("_pending_", lines[4], "unknown rows stay untouched")
        self.assertEqual(
            lines[8],
            "| sharded GEMV modeled req/s [placement=linear] | 123.46 |")

    def test_fills_gbps_columns_from_rate(self):
        lines = [
            "| workload | GB/s |",
            "|---|---|",
            "| `plane scatter 4x2 numa-balanced (GB/s)` | _pending_ |",
        ]
        n = fe.fill_perf(lines, self.TRANSFER)
        self.assertEqual(n, 1)
        self.assertEqual(
            lines[2], "| `plane scatter 4x2 numa-balanced (GB/s)` | 21.99 |")

    SERVING = doc({
        "chaos serving modeled req/s [seed=11]":
            {"minstr_per_s": 0.0, "rate": 87.654},
        "chaos goodput under faults (fraction) [seed=11]":
            {"minstr_per_s": 0.0, "rate": 1.0},
        "chaos recovery latency (modeled s, informational) [seed=11]":
            {"minstr_per_s": 0.0123},
    })

    def test_fills_serving_goodput_and_recovery_columns(self):
        lines = [
            "| workload | req/s (modeled) |",
            "|---|---|",
            "| chaos serving modeled req/s [seed=11] | _pending_ |",
            "",
            "| workload | goodput (fraction) |",
            "|---|---|",
            "| chaos goodput under faults (fraction) [seed=11] | _pending_ |",
            "",
            "| workload | recovery latency (modeled s) |",
            "|---|---|",
            "| chaos recovery latency (modeled s, informational) [seed=11] | _pending_ |",
        ]
        n = fe.fill_perf(lines, self.SERVING)
        self.assertEqual(n, 3)
        self.assertEqual(
            lines[2], "| chaos serving modeled req/s [seed=11] | 87.65 |")
        self.assertEqual(
            lines[6], "| chaos goodput under faults (fraction) [seed=11] | 1.000 |")
        self.assertEqual(
            lines[10],
            "| chaos recovery latency (modeled s, informational) [seed=11] | 0.0123 |")

    OPENLOOP = doc({
        "open-loop serving modeled req/s [seed=11 load=2.0x]":
            {"minstr_per_s": 0.0, "rate": 402.1},
        "open-loop shed rate (fraction, informational) [seed=11 load=2.0x]":
            {"minstr_per_s": 0.4167},
        "open-loop p95 latency (modeled ms, informational) [seed=11 load=2.0x]":
            {"minstr_per_s": 31.25},
    })

    def test_fills_open_loop_shed_and_latency_columns(self):
        lines = [
            "| workload | req/s (modeled) |",
            "|---|---|",
            "| open-loop serving modeled req/s [seed=11 load=2.0x] | _pending_ |",
            "",
            "| workload | shed rate |",
            "|---|---|",
            "| open-loop shed rate (fraction, informational) [seed=11 load=2.0x] | _pending_ |",
            "",
            "| workload | latency (modeled ms) |",
            "|---|---|",
            "| open-loop p95 latency (modeled ms, informational) [seed=11 load=2.0x] | _pending_ |",
        ]
        n = fe.fill_perf(lines, self.OPENLOOP)
        self.assertEqual(n, 3)
        self.assertEqual(
            lines[2],
            "| open-loop serving modeled req/s [seed=11 load=2.0x] | 402.10 |")
        self.assertEqual(
            lines[6],
            "| open-loop shed rate (fraction, informational) [seed=11 load=2.0x] | 0.417 |")
        self.assertEqual(
            lines[10],
            "| open-loop p95 latency (modeled ms, informational) [seed=11 load=2.0x] | 31.250 |")

    INTEGRITY = doc({
        "integrity serving modeled req/s [seed=11]":
            {"minstr_per_s": 0.0, "rate": 301.5},
        "integrity detection rate (fraction) [seed=11]":
            {"minstr_per_s": 0.0, "rate": 1.0},
        "integrity scrub overhead (fraction, informational) [seed=11]":
            {"minstr_per_s": 0.042},
        "integrity mean time-to-repair (modeled s, informational) [seed=11]":
            {"minstr_per_s": 0.0031},
    })

    def test_fills_integrity_detection_overhead_and_mttr_columns(self):
        lines = [
            "| workload | req/s (modeled) |",
            "|---|---|",
            "| integrity serving modeled req/s [seed=11] | _pending_ |",
            "",
            "| workload | detection rate (fraction) |",
            "|---|---|",
            "| integrity detection rate (fraction) [seed=11] | _pending_ |",
            "",
            "| workload | scrub overhead (fraction) |",
            "|---|---|",
            "| integrity scrub overhead (fraction, informational) [seed=11] | _pending_ |",
            "",
            "| workload | time-to-repair (modeled s) |",
            "|---|---|",
            "| integrity mean time-to-repair (modeled s, informational) [seed=11] | _pending_ |",
        ]
        n = fe.fill_perf(lines, self.INTEGRITY)
        self.assertEqual(n, 4)
        self.assertEqual(
            lines[2], "| integrity serving modeled req/s [seed=11] | 301.50 |")
        # Detection rate is gated: it fills from `rate`, not minstr.
        self.assertEqual(
            lines[6], "| integrity detection rate (fraction) [seed=11] | 1.000 |")
        # Overhead is a cost fraction riding in minstr — the "overhead"
        # rule must win over the generic fraction rule (which would read
        # the absent `rate` and print a dash).
        self.assertEqual(
            lines[10],
            "| integrity scrub overhead (fraction, informational) [seed=11] | 0.042 |")
        self.assertEqual(
            lines[14],
            "| integrity mean time-to-repair (modeled s, informational) [seed=11] | 0.0031 |")

    def test_ablation_parser_reads_marked_table_only(self):
        out = "\n".join([
            "noise | not | a | table row before the marker",
            "| workload | naive | all-on |",
            "markdown (paste into EXPERIMENTS.md §Pass ablation):",
            "| workload | naive | all-on |",
            "|---|---|---|",
            "| BSDP dot, 16T | 1000 | 800 |",
        ])
        rows = fe.ablation_rows(out)
        self.assertEqual(list(rows), ["BSDP dot, 16T"])
        self.assertEqual(rows["BSDP dot, 16T"], ["BSDP dot, 16T", "1000", "800"])

    HOTSPOT_MD = ("### Fleet GEMV — per-PC issue profile\n"
                  "1234 instrs across 42 distinct PCs\n"
                  "| rank | pc | instr | count |\n"
                  "|---|---|---|---|\n"
                  "| 1 | 12 | add | 999 |\n")

    def test_fill_hotspots_replaces_marker_block_idempotently(self):
        lines = [
            "## §Hotspots",
            "prose stays",
            fe.HOTSPOTS_BEGIN,
            "_pending_ — run the commands above.",
            fe.HOTSPOTS_END,
            "trailing prose stays",
        ]
        n = fe.fill_hotspots(lines, self.HOTSPOT_MD)
        self.assertEqual(n, 1)
        self.assertEqual(lines[2], fe.HOTSPOTS_BEGIN)
        self.assertEqual(lines[3], "### Fleet GEMV — per-PC issue profile")
        self.assertEqual(lines[-2], fe.HOTSPOTS_END)
        self.assertEqual(lines[-1], "trailing prose stays")
        self.assertNotIn("_pending_", "\n".join(lines))
        # Second fill overwrites the previous block, never accumulates.
        n = fe.fill_hotspots(lines, self.HOTSPOT_MD)
        self.assertEqual(n, 1)
        self.assertEqual(lines.count("### Fleet GEMV — per-PC issue profile"), 1)

    def test_fill_hotspots_without_markers_is_reported_not_fatal(self):
        lines = ["no markers here"]
        self.assertEqual(fe.fill_hotspots(lines, self.HOTSPOT_MD), 0)
        self.assertEqual(lines, ["no markers here"])

    def test_fill_ablation_respects_section_and_column_count(self):
        lines = [
            "## §Pass ablation",
            "| workload | naive | all-on |",
            "|---|---|---|",
            "| BSDP dot, 16T | _pending_ | _pending_ |",
            "## other section",
            "| BSDP dot, 16T | _pending_ | _pending_ |",
        ]
        rows = {"BSDP dot, 16T": ["BSDP dot, 16T", "1000", "800"]}
        n = fe.fill_ablation(lines, rows)
        self.assertEqual(n, 1)
        self.assertEqual(lines[3], "| BSDP dot, 16T | 1000 | 800 |")
        self.assertIn("_pending_", lines[5], "rows outside §Pass ablation untouched")


class MergeBenchJson(unittest.TestCase):
    def test_concatenates_in_order_with_meta_from_first(self):
        a = doc({"w1": {"minstr_per_s": 0.0, "rate": 1.0}},
                meta={"exec_tier": "stepped", "smoke": True})
        b = doc({"w2": {"minstr_per_s": 0.0, "rate": 2.0},
                 "w3": {"minstr_per_s": 0.5}},
                meta={"exec_tier": "ignored"})
        merged = mbj.merge([a, b])
        self.assertEqual(merged["schema_version"], 2)
        self.assertEqual(list(merged["workloads"]), ["w1", "w2", "w3"])
        self.assertEqual(merged["meta"], {"exec_tier": "stepped", "smoke": True})

    def test_identical_duplicates_collapse_conflicting_fail(self):
        a = doc({"w": {"rate": 1.0}})
        same = doc({"w": {"rate": 1.0}})
        self.assertEqual(list(mbj.merge([a, same])["workloads"]), ["w"])
        conflict = doc({"w": {"rate": 2.0}})
        with self.assertRaises(ValueError):
            mbj.merge([a, conflict])

    def test_rejects_wrong_schema(self):
        with self.assertRaises(ValueError):
            mbj.merge([doc({}, schema=1)])

    def test_cli_roundtrip(self):
        a = doc({"w1": {"rate": 1.0}}, meta={"exec_tier": "superblock"})
        b = doc({"w2": {"modeled_cycles": 7}})
        with tempfile.TemporaryDirectory() as d:
            pa, pb = os.path.join(d, "a.json"), os.path.join(d, "b.json")
            out = os.path.join(d, "merged.json")
            for p, v in [(pa, a), (pb, b)]:
                with open(p, "w") as f:
                    json.dump(v, f)
            self.assertEqual(mbj.main(["merge_bench_json.py", out, pa, pb]), 0)
            with open(out) as f:
                merged = json.load(f)
        self.assertEqual(list(merged["workloads"]), ["w1", "w2"])
        # The merged file is gate-ready: not a bootstrap placeholder.
        self.assertFalse(cpr.is_bootstrap(merged))


def ev(name, ts, dur, tid=0, **extra):
    e = {"name": name, "ph": "X", "ts": ts, "dur": dur, "pid": 0, "tid": tid}
    e.update(extra)
    return e


class TraceTools(unittest.TestCase):
    EVENTS = [
        ev("launch", 0.0, 12.5),
        ev("push", 12.5, 3.0, tid=1),
        ev("launch", 20.0, 12.5),
    ]

    def write(self, d, name, payload):
        path = os.path.join(d, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        return path

    def test_load_accepts_bare_array_and_wrapper_object(self):
        with tempfile.TemporaryDirectory() as d:
            bare = self.write(d, "bare.json", self.EVENTS)
            wrapped = self.write(d, "wrapped.json", {"traceEvents": self.EVENTS})
            self.assertEqual(tt.load_events(bare), self.EVENTS)
            self.assertEqual(tt.load_events(wrapped), self.EVENTS)
            scalar = self.write(d, "bad.json", {"not": "a trace"})
            with self.assertRaises(ValueError):
                tt.load_events(scalar)

    def test_validate_passes_complete_events_and_names_each_problem(self):
        self.assertEqual(tt.validate_events(self.EVENTS), [])
        bad = [
            {"ph": "X", "ts": 0, "dur": 1, "pid": 0, "tid": 0},   # no name
            ev("b", -1.0, 2.0),                                    # negative ts
            ev("c", 0.0, "fast"),                                  # non-numeric dur
            {"name": "d", "ph": "B", "ts": 0, "dur": 0, "pid": 0, "tid": 0},
            ev("e", 0.0, 1.0),                                     # fine
        ]
        problems = tt.validate_events(bad)
        self.assertEqual(len(problems), 4)
        self.assertIn("[0]: missing or empty 'name'", problems[0])
        self.assertIn("'ts' is negative", problems[1])
        self.assertIn("'dur' is not numeric", problems[2])
        self.assertIn("ph='B'", problems[3])

    def test_summarize_groups_by_kind_sorted(self):
        s = tt.summarize_events(self.EVENTS)
        self.assertEqual(list(s), ["launch", "push"])
        self.assertEqual(s["launch"], (2, 25.0))
        self.assertEqual(s["push"], (1, 3.0))

    def test_diff_flags_count_and_duration_drift(self):
        a = tt.summarize_events(self.EVENTS)
        self.assertEqual(tt.diff_summaries(a, dict(a)), [])
        b = tt.summarize_events(self.EVENTS[:2])     # one launch fewer
        problems = tt.diff_summaries(a, b)
        self.assertEqual(problems, ["kind launch: count 2 != 1"])
        c = tt.summarize_events([ev("launch", 0.0, 12.5), ev("push", 12.5, 3.0),
                                 ev("launch", 20.0, 13.0)])
        self.assertIn("total dur", tt.diff_summaries(a, c)[0])

    def test_cli_diff_exact_catches_reordered_streams(self):
        # Same per-kind totals, different order: plain diff passes, the
        # cross-tier --exact mode must fail.
        reordered = [self.EVENTS[1], self.EVENTS[0], self.EVENTS[2]]
        with tempfile.TemporaryDirectory() as d:
            a = self.write(d, "a.json", self.EVENTS)
            b = self.write(d, "b.json", reordered)
            self.assertEqual(tt.main(["diff", a, b]), 0)
            self.assertEqual(tt.main(["diff", a, b, "--exact"]), 1)
            self.assertEqual(tt.main(["diff", a, a, "--exact"]), 0)

    def test_cli_validate_exit_codes(self):
        with tempfile.TemporaryDirectory() as d:
            good = self.write(d, "good.json", self.EVENTS)
            bad = self.write(d, "bad.json", [{"name": "", "ph": "X"}])
            self.assertEqual(tt.main(["validate", good]), 0)
            self.assertEqual(tt.main(["validate", bad]), 1)
            self.assertEqual(tt.main(["summarize", good]), 0)
            self.assertEqual(
                tt.main(["validate", os.path.join(d, "absent.json")]), 1)


if __name__ == "__main__":
    unittest.main()
