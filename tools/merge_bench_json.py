#!/usr/bin/env python3
"""Merge schema-v2 bench reports into one report.

CI runs several serving benches per execution tier (chaos_serving,
open_loop_serving), each writing its own schema-v2 JSON; the cross-tier
consistency check and the committed-baseline gate want ONE file per
tier. This merges them:

    python3 tools/merge_bench_json.py OUT IN1 IN2 [IN3 ...]

Rules (pinned by tools/test_tools.py):
  * every input must be schema_version 2; the output is too;
  * workload rows concatenate in input order (insertion order is what
    check_perf_regression.py reports in);
  * the first input that carries a `meta` object donates it (all inputs
    come from the same tier run, so any copy is representative);
  * a workload name appearing in two inputs is an error unless the
    records are identical — silently keeping one would hide a bench
    accidentally measuring the same row twice with different numbers.
"""

import json
import sys


def merge(docs):
    """Merge parsed schema-v2 docs; raises ValueError on bad input."""
    merged = {"schema_version": 2}
    workloads = {}
    for d in docs:
        if d.get("schema_version") != 2:
            raise ValueError("input is not schema_version 2")
        if "meta" in d and "meta" not in merged:
            merged["meta"] = d["meta"]
        for name, rec in (d.get("workloads") or {}).items():
            if name in workloads and workloads[name] != rec:
                raise ValueError(f"conflicting duplicate workload: {name}")
            workloads[name] = rec
    merged["workloads"] = workloads
    return merged


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    out, inputs = argv[1], argv[2:]
    docs = []
    for path in inputs:
        with open(path) as f:
            docs.append(json.load(f))
    try:
        merged = merge(docs)
    except ValueError as e:
        print(f"FAIL: {e}")
        return 1
    with open(out, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    print(f"wrote {out}: {len(merged['workloads'])} workload(s) "
          f"from {len(inputs)} report(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
