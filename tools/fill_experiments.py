#!/usr/bin/env python3
"""Rewrite the `_pending_` cells of EXPERIMENTS.md from measured bench
output, so numbers land mechanically instead of by hand.

Three sources, all optional:

  --perf BENCH_perf.json      schema-v2 report written by
                              `cargo bench --bench perf_simulator`.
                              Fills §Perf tables: any markdown table row
                              whose first cell names a JSON workload
                              (backticks ignored) gets its `Minstr/s`
                              column filled with `minstr_per_s`, its
                              `modeled cycles` column with
                              `modeled_cycles` (aggregate rows without a
                              cycle count get an em dash), and any
                              `GB/s` / `req/s` / `rate` column with the
                              row's `rate` field.

  --transfer BENCH_transfer.json
                              schema-v2 report written by
                              `cargo bench --bench fig11_transfer`
                              (deterministic modeled rates). Same table
                              filling rules as --perf — used for the
                              §Placement ablation tables.

  --serving BENCH_serving.json
                              schema-v2 serving report: the output of
                              `cargo bench --bench chaos_serving`, of
                              `cargo bench --bench open_loop_serving`
                              (BENCH_serving_openloop.json), of
                              `cargo bench --bench integrity_serving`
                              (BENCH_serving_integrity.json), or any of
                              them merged via tools/merge_bench_json.py
                              (deterministic modeled req/s, goodput /
                              shed-rate / detection-rate fractions,
                              recovery and time-to-repair latencies,
                              scrub-overhead fractions, latency
                              percentiles in modeled ms). Same table
                              filling rules — used for the §Chaos,
                              §Open-loop serving and §Integrity tables.

  --ablation FILE             captured stdout of
                              `cargo bench --bench pass_ablation`, which
                              prints a markdown-pasteable table after the
                              "markdown (paste into EXPERIMENTS.md"
                              marker. Rows in §Pass ablation whose
                              workload cell matches a printed row are
                              replaced wholesale (column counts must
                              agree).

  --hotspots BENCH_hotspots.md
                              per-PC hotspot table written by
                              `PIM_PROFILE=1 cargo bench --bench
                              perf_simulator`. Replaces the §Hotspots
                              block between the `<!-- hotspots:begin -->`
                              and `<!-- hotspots:end -->` markers with
                              the file's contents verbatim.

Usage:
    cargo bench --bench perf_simulator
    cargo bench --bench fig11_transfer
    cargo bench --bench chaos_serving
    cargo bench --bench pass_ablation | tee pass_ablation.out
    python3 tools/fill_experiments.py --perf BENCH_perf.json \
        --transfer BENCH_transfer.json --serving BENCH_serving.json \
        --ablation pass_ablation.out

Idempotent: already-filled cells are overwritten with the new
measurement (the log's contract is "regenerated, never hand-edited");
rows with no matching measurement are left untouched and reported.
Exits 1 if nothing at all could be filled (likely a wiring error).
"""

import argparse
import json
import re
import sys

PENDING = "_pending_"
DASH = "—"  # em dash for rows with no modeled cycle count
HOTSPOTS_BEGIN = "<!-- hotspots:begin -->"
HOTSPOTS_END = "<!-- hotspots:end -->"


def norm(cell):
    """Normalize a workload cell for matching: strip backticks/space."""
    return cell.replace("`", "").strip()


def split_row(line):
    """Split a markdown table row into cells (no escaped pipes used)."""
    return [c.strip() for c in line.strip().strip("|").split("|")]


def is_table_row(line):
    s = line.strip()
    return s.startswith("|") and s.endswith("|") and not set(s) <= set("|-: ")


def is_separator(line):
    s = line.strip()
    return s.startswith("|") and set(s) <= set("|-: ")


def fmt_row(cells):
    return "| " + " | ".join(cells) + " |"


def fill_perf(lines, perf_doc):
    """Fill Minstr/s + modeled-cycle columns from the schema-v2 report."""
    rows = perf_doc.get("workloads") or {}
    by_name = {norm(k): v for k, v in rows.items()}
    filled = 0
    header_cols = []
    for i, line in enumerate(lines):
        if not is_table_row(line):
            continue
        cells = split_row(line)
        if is_separator(line):
            continue
        lowered = [c.lower() for c in cells]
        if "workload" in lowered[0].lower():
            header_cols = lowered
            continue
        if not header_cols or len(cells) != len(header_cols):
            continue
        rec = by_name.get(norm(cells[0]))
        if rec is None:
            continue
        changed = False
        for j, col in enumerate(header_cols):
            if "minstr" in col:
                cells[j] = f"{rec.get('minstr_per_s', 0.0):.1f}"
                changed = True
            elif "modeled cycles" in col:
                c = rec.get("modeled_cycles")
                cells[j] = str(c) if c is not None else DASH
                changed = True
            elif "gb/s" in col or "req/s" in col or col == "rate":
                r = rec.get("rate")
                cells[j] = f"{r:.2f}" if r is not None else DASH
                changed = True
            elif "overhead" in col:
                # Scrub overhead is a cost fraction (lower is better, the
                # inverse gating direction of `rate`), so it rides
                # ungated in the minstr field. Must match before the
                # generic fraction rule: its column also says "fraction".
                v = rec.get("minstr_per_s")
                cells[j] = f"{v:.3f}" if v is not None else DASH
                changed = True
            elif "goodput" in col or "fraction" in col:
                r = rec.get("rate")
                cells[j] = f"{r:.3f}" if r is not None else DASH
                changed = True
            elif "modeled s" in col:
                # Recovery-latency rows park their modeled seconds in the
                # (ungated) minstr field; 4 decimals, it is a small cost.
                v = rec.get("minstr_per_s")
                cells[j] = f"{v:.4f}" if v is not None else DASH
                changed = True
            elif "modeled ms" in col:
                # Open-loop latency percentiles: modeled milliseconds in
                # the (ungated) minstr field — a cost, not a rate.
                v = rec.get("minstr_per_s")
                cells[j] = f"{v:.3f}" if v is not None else DASH
                changed = True
            elif "shed" in col:
                # Shed rates are lower-is-better (the inverse gating
                # direction of `rate`), so they ride ungated in minstr.
                v = rec.get("minstr_per_s")
                cells[j] = f"{v:.3f}" if v is not None else DASH
                changed = True
        if changed:
            lines[i] = fmt_row(cells)
            filled += 1
    return filled


def ablation_rows(text):
    """Workload → printed markdown row, from pass_ablation stdout."""
    out = {}
    seen_marker = False
    for line in text.splitlines():
        if "markdown (paste into EXPERIMENTS.md" in line:
            seen_marker = True
            continue
        if not seen_marker or not is_table_row(line) or is_separator(line):
            continue
        cells = split_row(line)
        if not cells or cells[0].lower() == "workload":
            continue
        out[norm(cells[0])] = cells
    return out


def fill_ablation(lines, rows):
    """Replace §Pass ablation table rows with the bench's printed ones."""
    filled = 0
    in_section = False
    for i, line in enumerate(lines):
        if line.startswith("## "):
            in_section = "Pass ablation" in line
            continue
        if not in_section or not is_table_row(line) or is_separator(line):
            continue
        cells = split_row(line)
        new = rows.get(norm(cells[0]))
        if new is None or cells[0].lower() == "workload":
            continue
        if len(new) != len(cells):
            print(f"  skip (column mismatch {len(new)} vs {len(cells)}): {cells[0]}")
            continue
        # Keep the log's own workload label (it may carry backticks).
        merged = [cells[0]] + new[1:]
        lines[i] = fmt_row(merged)
        filled += 1
    return filled


def fill_hotspots(lines, md_text):
    """Replace the §Hotspots marker block with the profiler's markdown.

    Returns the number of blocks replaced (0 when the markers are
    missing or inverted — reported, not fatal, like unmatched rows).
    """
    try:
        begin = lines.index(HOTSPOTS_BEGIN)
        end = lines.index(HOTSPOTS_END)
    except ValueError:
        print(f"  skip: {HOTSPOTS_BEGIN} / {HOTSPOTS_END} markers not found")
        return 0
    if end <= begin:
        print("  skip: hotspots markers are inverted")
        return 0
    lines[begin + 1:end] = md_text.strip("\n").splitlines()
    return 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--perf", help="BENCH_perf.json (schema v2)")
    ap.add_argument("--transfer", help="BENCH_transfer.json (schema v2, modeled rates)")
    ap.add_argument("--serving", help="BENCH_serving.json (schema v2, chaos serving rates)")
    ap.add_argument("--ablation", help="captured stdout of the pass_ablation bench")
    ap.add_argument("--hotspots", help="BENCH_hotspots.md (per-PC profiler table)")
    ap.add_argument("--experiments", default="EXPERIMENTS.md")
    args = ap.parse_args()
    if not (args.perf or args.transfer or args.serving or args.ablation
            or args.hotspots):
        ap.error("give at least one of --perf / --transfer / --serving / "
                 "--ablation / --hotspots")

    with open(args.experiments) as f:
        lines = f.read().splitlines()

    total = 0
    for label, path in [
        ("§Perf", args.perf),
        ("§Placement", args.transfer),
        ("§Chaos", args.serving),
    ]:
        if not path:
            continue
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema_version") != 2:
            print(f"FAIL: {path} is not schema_version 2")
            return 1
        n = fill_perf(lines, doc)
        print(f"{label}: filled {n} row(s) from {path}")
        total += n
    if args.ablation:
        with open(args.ablation) as f:
            rows = ablation_rows(f.read())
        if not rows:
            print(f"FAIL: no markdown table found in {args.ablation} "
                  "(pass the bench's captured stdout)")
            return 1
        n = fill_ablation(lines, rows)
        print(f"§Pass ablation: filled {n} row(s) from {args.ablation}")
        total += n
    if args.hotspots:
        with open(args.hotspots) as f:
            md = f.read()
        if not md.strip():
            print(f"FAIL: {args.hotspots} is empty (run the profiling bench first)")
            return 1
        n = fill_hotspots(lines, md)
        print(f"§Hotspots: replaced {n} block(s) from {args.hotspots}")
        total += n

    pending = sum(1 for l in lines if PENDING in l)
    if total == 0:
        print("FAIL: nothing filled — workload names out of sync between "
              f"{args.experiments} and the measurement files?")
        return 1
    with open(args.experiments, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {args.experiments}; {pending} line(s) still carry {PENDING}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
