"""Pure-jnp / numpy reference oracles for the L1 Pallas kernels.

These are the correctness anchors of the Python layer: every Pallas
kernel in this package is asserted against them at build time (pytest),
and the encodings here mirror ``rust/src/kernels/encode.rs`` bit for bit
so the rust simulator, the Pallas kernels and the AOT artifacts all
agree on the INT4 bit-plane layout.
"""

import jax.numpy as jnp
import numpy as np

BLOCK = 32  # elements per bit-plane block (one bit per u32 lane)
PLANES = 4  # bit-planes per INT4 value


def gemv_i8_ref(m, x):
    """INT8 GEMV with i32 accumulation: y = m @ x."""
    return jnp.dot(m.astype(jnp.int32), x.astype(jnp.int32))


def dot_i4_ref(a, b):
    """Signed INT4 dot product (operands stored as i8 arrays)."""
    return jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32))


def bitplane_encode_i4(vals):
    """Bit-plane transpose signed INT4 values (numpy, host-side).

    Layout identical to rust ``encode::bitplane_encode_i4``: every block
    of 32 elements becomes four consecutive u32 words; word ``j`` holds
    bit ``j`` of each element, element ``lane`` at bit position ``lane``.
    """
    vals = np.asarray(vals, dtype=np.int8)
    assert vals.ndim == 1 and vals.size % BLOCK == 0
    assert vals.min(initial=0) >= -8 and vals.max(initial=0) <= 7
    nib = (vals.astype(np.uint8) & 0xF).reshape(-1, BLOCK)  # (nblocks, 32)
    lanes = np.arange(BLOCK, dtype=np.uint32)
    out = np.zeros((nib.shape[0], PLANES), dtype=np.uint32)
    for p in range(PLANES):
        bits = ((nib >> p) & 1).astype(np.uint32)
        out[:, p] = (bits << lanes).sum(axis=1, dtype=np.uint32)
    return out.reshape(-1)


def bitplane_decode_i4(planes):
    """Inverse of :func:`bitplane_encode_i4` (test helper)."""
    planes = np.asarray(planes, dtype=np.uint32).reshape(-1, PLANES)
    lanes = np.arange(BLOCK, dtype=np.uint32)
    vals = np.zeros((planes.shape[0], BLOCK), dtype=np.uint8)
    for p in range(PLANES):
        bits = ((planes[:, p : p + 1] >> lanes) & 1).astype(np.uint8)
        vals |= (bits << p).astype(np.uint8)
    vals = vals.reshape(-1).astype(np.int16)
    vals = np.where(vals >= 8, vals - 16, vals)
    return vals.astype(np.int8)


def bsdp_ref_planes(a_planes, b_planes):
    """Bit-serial dot product evaluated directly on plane words (numpy
    oracle for Algorithm 2, independent of the Pallas kernel)."""
    a = np.asarray(a_planes, dtype=np.uint32).reshape(-1, PLANES)
    b = np.asarray(b_planes, dtype=np.uint32).reshape(-1, PLANES)
    assert a.shape == b.shape
    acc = np.int64(0)
    for j in range(PLANES):
        for k in range(PLANES):
            popc = int(np.bitwise_count(a[:, j] & b[:, k]).astype(np.int64).sum())
            term = popc << (j + k)
            acc = acc - term if (j == 3) != (k == 3) else acc + term
    return int(acc)


def gemv_i4_ref(m_vals, x_vals):
    """Signed INT4 GEMV reference from raw (unencoded) values."""
    m = np.asarray(m_vals, dtype=np.int32)
    x = np.asarray(x_vals, dtype=np.int32)
    return (m @ x).astype(np.int32)


def requantize_i32_to_i8(h):
    """The L2 model's inter-layer requantization: arithmetic shift by 8,
    clip to int8. Must match the rust-side pipeline bit for bit."""
    return jnp.clip(h >> 8, -128, 127).astype(jnp.int8)


def mlp_i8_ref(w1, w2, x):
    """Reference for the 2-layer quantized MLP (L2 graph)."""
    h = gemv_i8_ref(w1, x)
    h = jnp.maximum(h, 0)
    h8 = requantize_i32_to_i8(h)
    return gemv_i8_ref(w2, h8)
