"""L1 Pallas kernel: bit-serial INT4 GEMV over bit-plane words.

The UPMEM kernel (paper §IV, Algorithm 2) evaluates 16 plane pairs per
32-element block with ``AND`` + ``cao`` (popcount) + ``lsl_add``. TPUs
have no popcount instruction, so the kernel uses the classic SWAR
popcount on the VPU — the *insight* (replace multiplies with bitwise
ops on transposed planes) carries over; the *instruction mapping*
changes, exactly the adaptation DESIGN.md §Hardware-Adaptation calls
for. ``interpret=True`` (see gemv.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PLANES = 4
BLOCK_ROWS = 64


def _popcount_u32(v):
    """SWAR population count of a uint32 array."""
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> 24


def _bsdp_gemv_kernel(mp_ref, xp_ref, o_ref):
    # mp: (block_rows, nblocks*4) u32; xp: (nblocks*4,) u32.
    mp = mp_ref[...]
    xp = xp_ref[...]
    rows, words = mp.shape
    m_planes = mp.reshape(rows, words // PLANES, PLANES)
    x_planes = xp.reshape(words // PLANES, PLANES)
    acc = jnp.zeros((rows,), dtype=jnp.int32)
    for j in range(PLANES):
        for k in range(PLANES):
            anded = m_planes[:, :, j] & x_planes[None, :, k]
            popc = _popcount_u32(anded).astype(jnp.int32)
            term = jnp.sum(popc, axis=1) << (j + k)
            if (j == 3) != (k == 3):
                acc = acc - term  # mixed plane-3 terms carry −2³
            else:
                acc = acc + term
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gemv_i4_bsdp(m_planes, x_planes, block_rows: int = BLOCK_ROWS):
    """Bit-serial signed INT4 GEMV.

    ``m_planes``: (rows, cols/32*4) uint32 — each row bit-plane encoded
    per ``ref.bitplane_encode_i4``; ``x_planes``: (cols/32*4,) uint32.
    Returns i32 (rows,).
    """
    rows, words = m_planes.shape
    assert rows % block_rows == 0
    assert x_planes.shape == (words,)
    assert words % PLANES == 0
    return pl.pallas_call(
        _bsdp_gemv_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, words), lambda i: (i, 0)),
            pl.BlockSpec((words,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.int32),
        interpret=True,
    )(m_planes, x_planes)


def vmem_bytes(block_rows: int, cols: int) -> int:
    """Static VMEM footprint of one grid step: plane words are 4 B per
    8 elements — half the INT8 tile size, the same 2× density the DPU
    kernel enjoys in MRAM."""
    words = cols // 32 * PLANES
    return block_rows * words * 4 + words * 4 + block_rows * 4
