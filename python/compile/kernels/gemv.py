"""L1 Pallas kernel: quantized INT8 GEMV.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the UPMEM kernel
streams 1 KB row chunks MRAM→WRAM per tasklet; on TPU the same schedule
is expressed with a ``BlockSpec`` grid — each grid step stages a
``(BLOCK_ROWS, cols)`` tile of the matrix plus the full vector into
VMEM and reduces it. ``interpret=True`` everywhere: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness (vs ``ref.py``) is
what the artifacts carry; TPU-side efficiency is *estimated* in
DESIGN.md §Perf from the VMEM footprint and MXU-utilization analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 64 rows × 1024 cols of int8 = 64 KB of matrix
# tile + 1 KB vector + 256 B accumulator per step — comfortably inside
# a TPU core's ~16 MB VMEM and aligned to the 8×128 VPU lane layout.
BLOCK_ROWS = 64


def _gemv_i8_kernel(m_ref, x_ref, o_ref):
    m = m_ref[...].astype(jnp.int32)
    x = x_ref[...].astype(jnp.int32)
    o_ref[...] = jnp.sum(m * x[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gemv_i8(m, x, block_rows: int = BLOCK_ROWS):
    """y[i8 m @ i8 x] with i32 accumulation via a row-tiled Pallas grid."""
    rows, cols = m.shape
    assert rows % block_rows == 0, f"rows {rows} must tile by {block_rows}"
    assert x.shape == (cols,)
    return pl.pallas_call(
        _gemv_i8_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.int32),
        interpret=True,
    )(m, x)


def vmem_bytes(block_rows: int, cols: int) -> int:
    """Static VMEM footprint of one grid step (DESIGN.md §Perf)."""
    return block_rows * cols + cols + block_rows * 4
