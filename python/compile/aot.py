"""AOT-lower the L2 graphs to HLO text for the rust PJRT runtime.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids, so text round-trips cleanly. Recipe from
/opt/xla-example/gen_hlo.py.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Shapes are fixed at lowering time (AOT) and must match
``rust/src/runtime/mod.rs``:

* gemv_int8:      m i8[256,1024],  x i8[1024]          -> (i32[256],)
* gemv_int4_bsdp: m u32[256,256],  x u32[256]          -> (i32[256],)
  (256 plane words = 2048 INT4 columns)
* mlp_int8:       w1 i8[1024,1024], w2 i8[64,1024], x i8[1024] -> (i32[64],)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

ORACLE_ROWS = 256
ORACLE_COLS = 1024
BSDP_COLS = 2048
BSDP_WORDS = BSDP_COLS // 32 * 4
MLP_HIDDEN = 1024
MLP_OUT = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifacts():
    """(name, function, example args) for every artifact."""
    return [
        (
            "gemv_int8",
            model.gemv_int8,
            (
                spec((ORACLE_ROWS, ORACLE_COLS), jnp.int8),
                spec((ORACLE_COLS,), jnp.int8),
            ),
        ),
        (
            "gemv_int4_bsdp",
            model.gemv_int4_bsdp,
            (
                spec((ORACLE_ROWS, BSDP_WORDS), jnp.uint32),
                spec((BSDP_WORDS,), jnp.uint32),
            ),
        ),
        (
            "mlp_int8",
            model.mlp_int8,
            (
                spec((MLP_HIDDEN, ORACLE_COLS), jnp.int8),
                spec((MLP_OUT, MLP_HIDDEN), jnp.int8),
                spec((ORACLE_COLS,), jnp.int8),
            ),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, example in artifacts():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
