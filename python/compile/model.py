"""L2: the JAX compute graphs that get AOT-lowered for the rust runtime.

Three exported functions, all calling the L1 Pallas kernels so the
kernels lower into the same HLO artifact:

* :func:`gemv_int8` — the INT8 GEMV used as numerical oracle and CPU
  comparator (Fig. 13's "server" path);
* :func:`gemv_int4_bsdp` — the bit-serial INT4 GEMV over plane words;
* :func:`mlp_int8` — a 2-layer quantized-MLP inference graph (the
  workload the serving example runs end to end: UPMEM simulator on the
  request path, this artifact as the cross-check oracle).

Python never runs at serving time: ``aot.py`` lowers these once to HLO
text and the rust runtime compiles/executes them via PJRT.
"""

import jax.numpy as jnp

from .kernels.bsdp import gemv_i4_bsdp
from .kernels.gemv import gemv_i8
from .kernels.ref import requantize_i32_to_i8


def gemv_int8(m, x):
    """y = m @ x (i8 → i32) via the Pallas GEMV kernel."""
    return (gemv_i8(m, x),)


def gemv_int4_bsdp(m_planes, x_planes):
    """Bit-serial INT4 GEMV over encoded planes (u32 → i32)."""
    return (gemv_i4_bsdp(m_planes, x_planes),)


def mlp_int8(w1, w2, x):
    """Two-layer quantized MLP: logits = w2 @ q(relu(w1 @ x)).

    The hidden layer is requantized to int8 with an arithmetic shift —
    the same fixed-point pipeline the rust serving example executes on
    the DPU simulator, so outputs must match exactly.
    """
    h = gemv_i8(w1, x)
    h = jnp.maximum(h, 0)
    h8 = requantize_i32_to_i8(h)
    return (gemv_i8(w2, h8),)
