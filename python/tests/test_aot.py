"""L2 AOT path: every artifact lowers to parseable HLO text and the
lowered executable agrees with the reference on random inputs."""

import numpy as np
import jax
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize(
    "name,fn,example", aot.artifacts(), ids=[a[0] for a in aot.artifacts()]
)
def test_artifact_lowers_to_hlo_text(name, fn, example):
    lowered = jax.jit(fn).lower(*example)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # No Mosaic custom-calls may leak into the artifact (interpret=True
    # keeps the Pallas kernels executable on the CPU PJRT client).
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_gemv_int8_compiled_matches_ref():
    rng = np.random.default_rng(7)
    m = rng.integers(-128, 128, size=(aot.ORACLE_ROWS, aot.ORACLE_COLS)).astype(np.int8)
    x = rng.integers(-128, 128, size=aot.ORACLE_COLS).astype(np.int8)
    (got,) = jax.jit(model.gemv_int8)(m, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gemv_i8_ref(m, x)))


def test_gemv_int4_bsdp_compiled_matches_ref():
    rng = np.random.default_rng(8)
    m = rng.integers(-8, 8, size=(aot.ORACLE_ROWS, aot.BSDP_COLS)).astype(np.int8)
    x = rng.integers(-8, 8, size=aot.BSDP_COLS).astype(np.int8)
    mp = np.stack([ref.bitplane_encode_i4(r) for r in m])
    xp = ref.bitplane_encode_i4(x)
    (got,) = jax.jit(model.gemv_int4_bsdp)(mp, xp)
    np.testing.assert_array_equal(np.asarray(got), ref.gemv_i4_ref(m, x))


def test_artifact_shapes_match_rust_runtime():
    # rust/src/runtime/mod.rs bakes these: keep in lockstep.
    assert aot.ORACLE_ROWS == 256
    assert aot.ORACLE_COLS == 1024
    assert aot.BSDP_WORDS == 256
    assert aot.MLP_HIDDEN == 1024
    assert aot.MLP_OUT == 64
