"""L1 correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes and values; every property pits the
interpret-mode Pallas kernel against ``ref.py``. This is the build-time
gate the AOT artifacts depend on.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bsdp import gemv_i4_bsdp
from compile.kernels.gemv import gemv_i8

SETTLE = dict(max_examples=25, deadline=None)


def rand_i8(rng, shape):
    return rng.integers(-128, 128, size=shape, dtype=np.int64).astype(np.int8)


def rand_i4(rng, shape):
    return rng.integers(-8, 8, size=shape, dtype=np.int64).astype(np.int8)


# ---------------------------------------------------------------- GEMV i8


@settings(**SETTLE)
@given(
    rows_t=st.integers(1, 4),
    cols=st.sampled_from([128, 256, 1024]),
    seed=st.integers(0, 2**32 - 1),
)
def test_gemv_i8_matches_ref(rows_t, cols, seed):
    rng = np.random.default_rng(seed)
    rows = 64 * rows_t
    m = rand_i8(rng, (rows, cols))
    x = rand_i8(rng, cols)
    got = np.asarray(gemv_i8(m, x))
    want = np.asarray(ref.gemv_i8_ref(m, x))
    np.testing.assert_array_equal(got, want)


def test_gemv_i8_extremes():
    m = np.full((64, 128), -128, dtype=np.int8)
    x = np.full(128, -128, dtype=np.int8)
    got = np.asarray(gemv_i8(m, x))
    assert (got == 128 * 128 * 128).all()


def test_gemv_i8_rejects_untiled_rows():
    m = np.zeros((65, 128), dtype=np.int8)
    x = np.zeros(128, dtype=np.int8)
    with pytest.raises(AssertionError):
        gemv_i8(m, x)


# ------------------------------------------------------------- encodings


@settings(**SETTLE)
@given(nblocks=st.integers(1, 8), seed=st.integers(0, 2**32 - 1))
def test_bitplane_roundtrip(nblocks, seed):
    rng = np.random.default_rng(seed)
    vals = rand_i4(rng, 32 * nblocks)
    planes = ref.bitplane_encode_i4(vals)
    assert planes.dtype == np.uint32
    assert planes.size == nblocks * 4
    np.testing.assert_array_equal(ref.bitplane_decode_i4(planes), vals)


def test_bitplane_layout_matches_rust():
    # 32 copies of 0b0101 -> planes 0 and 2 all-ones (mirrors the rust
    # unit test `plane_words_have_expected_structure`).
    vals = np.full(32, 0b0101, dtype=np.int8)
    planes = ref.bitplane_encode_i4(vals)
    assert list(planes) == [0xFFFFFFFF, 0, 0xFFFFFFFF, 0]


@settings(**SETTLE)
@given(nblocks=st.integers(1, 6), seed=st.integers(0, 2**32 - 1))
def test_bsdp_plane_oracle_matches_direct_dot(nblocks, seed):
    rng = np.random.default_rng(seed)
    a = rand_i4(rng, 32 * nblocks)
    b = rand_i4(rng, 32 * nblocks)
    got = ref.bsdp_ref_planes(ref.bitplane_encode_i4(a), ref.bitplane_encode_i4(b))
    assert got == int(np.asarray(ref.dot_i4_ref(a, b)))


# ------------------------------------------------------------ BSDP GEMV


@settings(**SETTLE)
@given(
    rows_t=st.integers(1, 2),
    cols=st.sampled_from([256, 512, 2048]),
    seed=st.integers(0, 2**32 - 1),
)
def test_bsdp_gemv_matches_ref(rows_t, cols, seed):
    rng = np.random.default_rng(seed)
    rows = 64 * rows_t
    m = rand_i4(rng, (rows, cols))
    x = rand_i4(rng, cols)
    mp = np.stack([ref.bitplane_encode_i4(r) for r in m])
    xp = ref.bitplane_encode_i4(x)
    got = np.asarray(gemv_i4_bsdp(mp, xp))
    want = ref.gemv_i4_ref(m, x)
    np.testing.assert_array_equal(got, want)


def test_bsdp_gemv_extremes():
    rows, cols = 64, 256
    m = np.full((rows, cols), -8, dtype=np.int8)
    x = np.full(cols, -8, dtype=np.int8)
    mp = np.stack([ref.bitplane_encode_i4(r) for r in m])
    xp = ref.bitplane_encode_i4(x)
    got = np.asarray(gemv_i4_bsdp(mp, xp))
    assert (got == 64 * cols).all()


# ---------------------------------------------------------------- model


@settings(**SETTLE)
@given(seed=st.integers(0, 2**32 - 1))
def test_mlp_graph_matches_ref(seed):
    from compile import model

    rng = np.random.default_rng(seed)
    w1 = rand_i8(rng, (1024, 1024))
    w2 = rand_i8(rng, (64, 1024))
    x = rand_i8(rng, 1024)
    (got,) = model.mlp_int8(w1, w2, x)
    want = ref.mlp_i8_ref(w1, w2, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requantize_semantics():
    import jax.numpy as jnp

    h = jnp.array([-100000, -256, -1, 0, 255, 256, 100000], dtype=jnp.int32)
    q = np.asarray(ref.requantize_i32_to_i8(h))
    # arithmetic shift: -1 >> 8 == -1, -256 >> 8 == -1
    assert list(q) == [-128, -1, -1, 0, 0, 1, 127]
