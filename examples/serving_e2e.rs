//! End-to-end serving driver (deliverable (b); EXPERIMENTS.md E8
//! decomposes the GEMV speedup this demo's serving path is built on).
//!
//! A quantized 2-layer MLP (w1: 1024×1024 INT8, w2: 64×1024 INT8) is
//! deployed GEMV-V style: **both weight matrices preloaded into
//! simulated PIM**, one DPU set per layer, the inter-layer
//! ReLU/requantize running on the host — the inference pattern §VI
//! motivates ("matrix preloaded … common in AI model inference").
//! Batched requests flow through the L3 serving stack (router →
//! batcher → per-layer coordinator), latency and throughput are
//! reported, and — when `make artifacts` has been run — every response
//! is cross-checked against the AOT-compiled JAX/Pallas artifact
//! executed via PJRT, proving all three layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serving_e2e
//! ```

use std::time::Instant;

use upmem_unleashed::coordinator::metrics::LatencyRecorder;
use upmem_unleashed::coordinator::GemvCoordinator;
use upmem_unleashed::host::{AllocPolicy, PimSystem};
use upmem_unleashed::kernels::gemv::GemvVariant;
use upmem_unleashed::runtime::{artifacts_available, MlpOracle, XlaRuntime};
use upmem_unleashed::transfer::topology::SystemTopology;
use upmem_unleashed::util::rng::Rng;

const COLS: u32 = 1024;
const HIDDEN: u32 = 1024;
const OUT: u32 = 64;
const REQUESTS: usize = 32;
const BATCH: usize = 8;
const TASKLETS: usize = 16;

fn requantize(h: &[i32]) -> Vec<i8> {
    h.iter().map(|&v| (v.max(0) >> 8).clamp(-128, 127) as i8).collect()
}

fn main() -> upmem_unleashed::Result<()> {
    println!("== UPMEM-Unleashed end-to-end serving demo (quantized MLP, GEMV-V) ==");
    let mut rng = Rng::new(2025);
    let w1 = rng.i8_vec((HIDDEN * COLS) as usize);
    let w2 = rng.i8_vec((OUT * HIDDEN) as usize);

    // One DPU set per layer, allocated NUMA/channel-balanced.
    let mut sys = PimSystem::new(SystemTopology::paper_server(), AllocPolicy::NumaAware);
    let set1 = sys.alloc_ranks(2)?;
    println!("layer 1: {} DPUs on ranks {:?}", set1.nr_dpus(), set1.ranks.ranks);
    let mut layer1 = GemvCoordinator::new(sys, set1, GemvVariant::I8Opt, TASKLETS);
    let t_load = Instant::now();
    let load1_s = layer1.preload_matrix(HIDDEN, COLS, &w1)?;

    let mut sys2 = PimSystem::new(SystemTopology::paper_server(), AllocPolicy::NumaAware);
    let set2 = sys2.alloc_ranks(2)?;
    println!("layer 2: {} DPUs on ranks {:?}", set2.nr_dpus(), set2.ranks.ranks);
    let mut layer2 = GemvCoordinator::new(sys2, set2, GemvVariant::I8Opt, TASKLETS);
    let load2_s = layer2.preload_matrix(OUT, HIDDEN, &w2)?;
    println!(
        "weights resident in PIM: modeled {:.2} ms transfer, {:.2} s host wall \
         (amortized over all requests — the GEMV-V scenario)",
        (load1_s + load2_s) * 1e3,
        t_load.elapsed().as_secs_f64()
    );

    // The XLA oracle (L1/L2 artifact) if built.
    let oracle = if artifacts_available() {
        let rt = XlaRuntime::cpu()?;
        println!("PJRT CPU client up: cross-checking every response against mlp_int8.hlo.txt");
        Some(MlpOracle::load(&rt)?)
    } else {
        println!("artifacts missing (run `make artifacts`) — skipping XLA cross-check");
        None
    };

    // Serve the requests through the two PIM layers, SDK-v2 style:
    // each batch runs through `gemv_pipelined`, which double-buffers
    // the x vector and overlaps request k+1's broadcast with request
    // k's compute on the async rank queues.
    let mut e2e = LatencyRecorder::new();
    let mut device_s_total = 0.0;
    let mut overlap_s_total = 0.0;
    let mut checked = 0usize;
    let xs: Vec<Vec<i8>> = (0..REQUESTS).map(|_| rng.i8_vec(COLS as usize)).collect();
    let t0 = Instant::now();
    for (b, batch) in xs.chunks(BATCH).enumerate() {
        let t_req = Instant::now();
        let views: Vec<&[i8]> = batch.iter().map(|v| v.as_slice()).collect();
        let (hs, t1) = layer1.gemv_pipelined(&views)?;
        let h8s: Vec<Vec<i8>> = hs.iter().map(|h| requantize(h)).collect();
        let h8views: Vec<&[i8]> = h8s.iter().map(|v| v.as_slice()).collect();
        let (logits, t2) = layer2.gemv_pipelined(&h8views)?;
        e2e.record(t_req.elapsed());
        device_s_total += t1.total() + t2.total();
        overlap_s_total += t1.overlap_s + t2.overlap_s;
        if let Some(oracle) = &oracle {
            for (i, (x, l)) in batch.iter().zip(&logits).enumerate() {
                let want = oracle.forward(&w1, &w2, x)
                    .map_err(|e| upmem_unleashed::Error::Runtime(e.to_string()))?;
                assert_eq!(l, &want, "batch {b} request {i}: simulator != XLA artifact");
                checked += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let s = e2e.summary().unwrap();
    println!("\nserved {REQUESTS} requests ({BATCH} per pipelined batch) in {wall:.2}s host wall time");
    println!(
        "host-side latency per batch: p50 {:.1} ms, p95 {:.1} ms (simulation cost)",
        s.p50 / 1e3,
        s.p95 / 1e3
    );
    println!(
        "async overlap: {:.3} ms of transfer hidden under compute ({:.1}% of device time)",
        overlap_s_total * 1e3,
        100.0 * overlap_s_total / (device_s_total + overlap_s_total)
    );
    println!(
        "modeled device time: {:.3} ms/request -> {:.0} req/s on the simulated PIM fleet",
        device_s_total / REQUESTS as f64 * 1e3,
        REQUESTS as f64 / device_s_total
    );
    let macs = (HIDDEN * COLS + OUT * HIDDEN) as f64;
    println!(
        "modeled inference throughput: {:.1} GOPS (2 x {macs:.0} MACs / device-s)",
        2.0 * macs * REQUESTS as f64 / device_s_total / 1e9
    );
    match oracle {
        Some(_) => println!(
            "cross-check: {checked}/{REQUESTS} responses bit-exact vs the AOT Pallas/JAX \
             artifact — L1 (Pallas) = L2 (JAX) = L3 (rust simulator) agree"
        ),
        None => println!("cross-check skipped (no artifacts)"),
    }
    Ok(())
}
