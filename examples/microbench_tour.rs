//! A tour of every §III/§IV kernel variant: the condensed, one-binary
//! version of Figures 3, 6, 7, 8 and 9, with the paper's expectations
//! printed next to each measurement.
//!
//! ```sh
//! cargo run --release --offline --example microbench_tour
//! ```

use upmem_unleashed::bench_support::table::{f1, f2, Table};
use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec, Unroll};
use upmem_unleashed::kernels::bsdp::{run_dot_microbench, DotVariant};

const KB: u32 = 176; // divides across 1..16 tasklets evenly

fn main() -> upmem_unleashed::Result<()> {
    // --- tasklet ramp (Fig. 3) ------------------------------------
    let mut ramp = Table::new(
        "Tasklet ramp — INT8 ADD (Fig. 3 shape: linear to 11, then flat)",
        &["tasklets", "MOPS"],
    );
    for t in [1usize, 2, 4, 8, 11, 16] {
        let m = run_microbench(Spec::add(DType::I8), t, KB * 1024, 1)?.mops;
        ramp.row(&[t.to_string(), f1(m)]);
    }
    ramp.print();

    // --- multiplication variants (Figs. 6 & 7) ---------------------
    let mut mul = Table::new(
        "Multiplication variants at 16 tasklets (Figs. 6-7)",
        &["kernel", "MOPS", "paper says"],
    );
    let m = |s: Spec| run_microbench(s, 16, KB * 1024, 1).map(|o| o.mops);
    let rows: Vec<(&str, Spec, &str)> = vec![
        ("INT8 MUL baseline", Spec::mul(DType::I8, MulImpl::Mulsi3), "2.7x below ADD"),
        ("INT8 MUL NI", Spec::mul(DType::I8, MulImpl::Native), "== INT8 ADD (80)"),
        ("INT8 MUL NIx4", Spec::mul(DType::I8, MulImpl::NativeX4), "between NI and NIx8"),
        ("INT8 MUL NIx8", Spec::mul(DType::I8, MulImpl::NativeX8), "+80% over NI, ~5x base"),
        ("INT32 MUL baseline", Spec::mul(DType::I32, MulImpl::Mulsi3), "6x below INT32 ADD"),
        ("INT32 MUL DIM", Spec::mul(DType::I32, MulImpl::Dim), "+16% over baseline"),
    ];
    for (name, spec, paper) in rows {
        mul.row(&[name.to_string(), f1(m(spec)?), paper.to_string()]);
    }
    mul.print();

    // --- unrolling (Fig. 8), including the IRAM-overfill case ------
    let mut un = Table::new(
        "Unrolling (Fig. 8) — 'IRAM!' reproduces the paper's linker error",
        &["kernel", "none", "x64", "auto"],
    );
    for (name, spec) in [
        ("INT8 ADD", Spec::add(DType::I8)),
        ("INT32 ADD", Spec::add(DType::I32)),
        ("INT32 MUL DIM", Spec::mul(DType::I32, MulImpl::Dim)),
    ] {
        let cell = |u| -> upmem_unleashed::Result<String> {
            match run_microbench(spec.with_unroll(u), 16, KB * 1024, 1) {
                Ok(o) => Ok(f1(o.mops)),
                Err(upmem_unleashed::Error::IramOverflow { .. }) => Ok("IRAM!".into()),
                Err(e) => Err(e),
            }
        };
        un.row(&[name.to_string(), cell(Unroll::No)?, cell(Unroll::X64)?, cell(Unroll::Auto)?]);
    }
    un.print();

    // --- bit-serial dot product (Fig. 9) ----------------------------
    let mut dot = Table::new(
        "INT4 dot product (Fig. 9, normalized to native baseline)",
        &["kernel", "M MAC/s", "normalized"],
    );
    let base = run_dot_microbench(DotVariant::NativeBaseline, 16, 64 * 1024, 1)?.mmacs;
    for v in [DotVariant::NativeBaseline, DotVariant::NativeOptimized, DotVariant::Bsdp] {
        let r = run_dot_microbench(v, 16, 64 * 1024, 1)?.mmacs;
        dot.row(&[v.name().to_string(), f1(r), f2(r / base)]);
    }
    dot.print();
    println!("paper: BSDP > 2.7x baseline, > 1.2x the optimized native kernel.");
    Ok(())
}
