//! The §V data-transfer story, end to end: allocate DPU ranks with the
//! SDK baseline vs the paper's NUMA/channel-aware extension (Fig. 10
//! API shape), transfer 32 MB blocks in parallel mode both ways, and
//! watch the throughput and the run-to-run variability.
//!
//! ```sh
//! cargo run --release --offline --example transfer_numa
//! ```

use upmem_unleashed::alloc::numa::equal_channel_distribution;
use upmem_unleashed::bench_support::table::{f2, Table};
use upmem_unleashed::host::{AllocPolicy, PimSystem, PullPlan, XferPlan};
use upmem_unleashed::transfer::topology::SystemTopology;

use upmem_unleashed::util::stats::Summary;

fn main() -> upmem_unleashed::Result<()> {
    let ranks = 4; // the paper's peak-throughput allocation size
    let bytes = 32u64 << 20; // 32 MB per rank, "for optimal performance"
    let total = bytes * ranks as u64;

    // The paper's Fig. 10 extension: balance each socket's share across
    // its memory channels.
    println!(
        "equal_channel_distribution({}, node 0) = {:?}  (ranks per channel)",
        ranks / 2,
        equal_channel_distribution(ranks / 2, 0)
    );

    let mut table = Table::new(
        "4-rank parallel transfers, 20 simulated boots (GB/s)",
        &["path", "mean", "min", "max", "spread"],
    );
    for (label, policy_of_boot) in [
        (
            "NUMA-aware  h2p",
            Box::new(|_b: u64| AllocPolicy::NumaAware) as Box<dyn Fn(u64) -> AllocPolicy>,
        ),
        ("baseline SDK h2p", Box::new(|b: u64| AllocPolicy::BaselineSdk { boot_seed: b })),
    ] {
        let mut samples = Vec::new();
        for boot in 0..20 {
            let mut sys =
                PimSystem::new(SystemTopology::paper_server(), policy_of_boot(boot));
            let set = sys.alloc_ranks(ranks)?;
            let report = sys.push_parallel_modeled(&set, total);
            samples.push(report.gbps());
        }
        let s = Summary::of(&samples);
        table.row(&[label.to_string(), f2(s.mean), f2(s.min), f2(s.max), f2(s.spread())]);
    }
    // PIM→host direction (sync-read transpose — the slow one).
    for (label, policy_of_boot) in [
        (
            "NUMA-aware  p2h",
            Box::new(|_b: u64| AllocPolicy::NumaAware) as Box<dyn Fn(u64) -> AllocPolicy>,
        ),
        ("baseline SDK p2h", Box::new(|b: u64| AllocPolicy::BaselineSdk { boot_seed: b })),
    ] {
        let mut samples = Vec::new();
        for boot in 0..20 {
            let mut sys =
                PimSystem::new(SystemTopology::paper_server(), policy_of_boot(boot));
            let set = sys.alloc_ranks(ranks)?;
            samples.push(sys.pull_parallel_modeled(&set, total).gbps());
        }
        let s = Summary::of(&samples);
        table.row(&[label.to_string(), f2(s.mean), f2(s.min), f2(s.max), f2(s.spread())]);
    }
    table.print();

    // Show where the ranks actually landed in one boot of each policy.
    let mut numa = PimSystem::new(SystemTopology::paper_server(), AllocPolicy::NumaAware);
    let sn = numa.alloc_ranks(ranks)?;
    let mut base = PimSystem::new(
        SystemTopology::paper_server(),
        AllocPolicy::BaselineSdk { boot_seed: 7 },
    );
    let sb = base.alloc_ranks(ranks)?;
    let describe = |name: &str, set: &upmem_unleashed::host::DpuSet, topo: &SystemTopology| {
        println!(
            "{name}: ranks {:?} span {} channels / {} sockets / {} DIMMs",
            set.ranks.ranks,
            set.ranks.channels_spanned(topo),
            set.ranks.sockets_spanned(topo),
            set.ranks.dimms_spanned(topo),
        );
    };
    describe("NUMA-aware ", &sn, numa.topology());
    describe("baseline   ", &sb, base.topology());

    // SDK-v2 zero-copy plans: one borrowed view per DPU, no per-DPU
    // allocations (`dpu_prepare_xfer`/`dpu_push_xfer` style). Moves
    // real bytes through simulated MRAM, unlike the modeled runs above.
    let chunk = 4096usize;
    let data: Vec<u8> = (0..sn.nr_dpus() * chunk).map(|i| i as u8).collect();
    let mut push_plan = XferPlan::to_pim(&sn, 0x10_0000);
    push_plan.prepare_chunks(&data, chunk)?;
    let push = numa.push_xfer(&sn, &push_plan)?;
    let mut out = vec![0u8; data.len()];
    let mut pull_plan = PullPlan::from_pim(&sn, 0x10_0000);
    pull_plan.prepare_chunks(&mut out, chunk)?;
    let pull = numa.pull_xfer(&sn, &mut pull_plan)?;
    assert_eq!(out, data);
    println!(
        "\nzero-copy XferPlan roundtrip over {} DPUs x {chunk} B: \
         push {:.2} GB/s, pull {:.2} GB/s, bytes verified",
        sn.nr_dpus(),
        push.gbps(),
        pull.gbps()
    );
    println!(
        "\npaper §V-C: ours peaks at 4 ranks with ~0.3 GB/s run-to-run spread; the\n\
         baseline lands on 1-3 DIMMs of one socket and fluctuates by 2-4 GB/s."
    );
    Ok(())
}
