//! Add a PIM kernel in under 50 lines: declare the streams, write the
//! per-element body, and the kernel framework (`rust/src/framework/`)
//! generates tasklet distribution, MRAM chunk iteration, WRAM staging,
//! DMA double-buffering and the unroll-ready element loops — then the
//! standard optimizer passes apply as if the kernel were hand-written.
//!
//! ```sh
//! cargo run --release --offline --example framework
//! ```

use upmem_unleashed::dpu::Dpu;
use upmem_unleashed::framework::{
    ChunkKernel, ChunkSpec, Dir, Dist, ElemCtx, ElemWidth, Hooks, KernelArgs, Stream,
};
use upmem_unleashed::kernels::{MRAM_A, MRAM_B};
use upmem_unleashed::opt::PassConfig;

const MRAM_C: u32 = 0x200_0000;

fn main() -> upmem_unleashed::Result<()> {
    // 1. Declare the data streams and chunking. Everything else —
    //    frames, pointers, loops, barriers — is derived from this.
    let k = ChunkKernel::map(ChunkSpec {
        name: "saxpyish",
        streams: vec![
            Stream { name: "a", mram_base: MRAM_A, elem: ElemWidth::I32, dir: Dir::In },
            Stream { name: "b", mram_base: MRAM_B, elem: ElemWidth::I32, dir: Dir::In },
            Stream { name: "c", mram_base: MRAM_C, elem: ElemWidth::I32, dir: Dir::Out },
        ],
        chunk_elems: 256,
        unroll: 8,
        dist: Dist::Cyclic,
        scratch_bytes: 0,
    });
    // 2. The body: c = 2*a + b, on registers the framework hands you.
    let mut body = |pb: &mut upmem_unleashed::dpu::builder::ProgramBuilder, ctx: &ElemCtx| {
        pb.lsl(ctx.out, ctx.inputs[0], 1);
        pb.add(ctx.out, ctx.out, ctx.inputs[1]);
    };
    let prog = k.build(&PassConfig::all(), &mut Hooks::new(&mut body))?;
    // 3. Stage, launch, read back — the usual host flow.
    let n = 10_000usize;
    let (a, b): (Vec<i32>, Vec<i32>) =
        (0..n as i32).map(|v| (v, 3 * v)).unzip();
    let mut dpu = Dpu::new();
    dpu.load_program(&prog)?;
    dpu.mram.write_i32_slice(MRAM_A, &a).unwrap();
    dpu.mram.write_i32_slice(MRAM_B, &b).unwrap();
    KernelArgs::for_elems(n, 256, 16).write(&mut dpu.wram);
    let launch = dpu.launch(16)?;
    let c = dpu.mram.read_i32_slice(MRAM_C, n).unwrap();
    assert!(c.iter().enumerate().all(|(i, &v)| v == 5 * i as i32));
    println!("c = 2a + b verified for {n} elements in {} modeled cycles", launch.cycles);
    Ok(())
}
