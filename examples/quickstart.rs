//! Quickstart: the paper's headline fix in 30 lines.
//!
//! The UPMEM compiler lowers `int8 * int8` to a `__mulsi3` call even
//! though the ISA has a one-cycle byte multiply. Run the Fig. 2
//! microbenchmark both ways on the simulated DPU and see the gap, then
//! apply 64-bit loads (NI×8) and unrolling for the full ~8× of §III.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use upmem_unleashed::kernels::arith::{run_microbench, DType, MulImpl, Spec, Unroll};

fn main() -> upmem_unleashed::Result<()> {
    let tasklets = 16; // ≥11 keeps the 14-stage pipeline full (Fig. 3)
    let buf = 1024 * 1024; // the paper's 1M-element INT8 buffer

    println!("INT8 scalar multiplication on one simulated UPMEM DPU:");
    let mut baseline_mops = 0.0;
    for (label, spec) in [
        ("compiler baseline (__mulsi3 call)", Spec::mul(DType::I8, MulImpl::Mulsi3)),
        ("native instruction (mul_sl_sl)  ", Spec::mul(DType::I8, MulImpl::Native)),
        ("+ 64-bit block loads (NIx8)     ", Spec::mul(DType::I8, MulImpl::NativeX8)),
        (
            "+ #pragma unroll 64             ",
            Spec::mul(DType::I8, MulImpl::NativeX8).with_unroll(Unroll::X64),
        ),
    ] {
        // Runs the kernel on the cycle-level simulator and verifies
        // every output byte against the host reference.
        let out = run_microbench(spec, tasklets, buf, 42)?;
        if baseline_mops == 0.0 {
            baseline_mops = out.mops;
        }
        println!(
            "  {label}  {:6.1} MOPS  ({:.2}x baseline)",
            out.mops,
            out.mops / baseline_mops
        );
    }
    println!("\npaper §III: NI matches INT8 ADD; NIx8+unroll ≈ 5.9x the baseline.");
    Ok(())
}
